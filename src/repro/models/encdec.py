"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment the conv/mel frontend is a STUB: `input_specs()` feeds
precomputed frame embeddings (B, enc_len, d_model).  The transformer
backbone is faithful: pre-LN layernorm blocks, GELU MLPs, MHA (kv = heads),
sinusoidal positions, 24 encoder + 24 decoder layers at the assigned dims.

CAMformer applies to both decoder self-attention (causal CAM search over the
growing cache) and cross-attention (paper Sec. IV-C: "encoder-decoder models
via non-causal search over encoder keys").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import (attn_cache_spec, attn_specs,
                                    attention_block)
from repro.models.transformer import ModelDef, _last_logits, dtype_of, stack_specs
from repro.sharding.partitioning import constrain

__all__ = ["make_model_def"]


def _enc_block_specs(cfg):
    return {"ln1": L.norm_specs(cfg), "attn": attn_specs(cfg),
            "ln2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}


def _dec_block_specs(cfg):
    return {
        "ln1": L.norm_specs(cfg), "self_attn": attn_specs(cfg),
        "ln_cross": L.norm_specs(cfg), "cross_attn": attn_specs(cfg),
        "ln2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg),
    }


def specs(cfg):
    return {
        "embed": L.embed_specs(cfg),
        "enc_blocks": stack_specs(_enc_block_specs(cfg), cfg.enc_layers),
        "enc_ln_f": L.norm_specs(cfg),
        "dec_blocks": stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg),
    }


def encode(params, features, cfg):
    """features: (B, enc_len, d_model) stub frame embeddings -> memory."""
    dt = dtype_of(cfg)
    b, s, _ = features.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = features.astype(dt) + L.sinusoidal_positions(pos, cfg.d_model).astype(dt)
    x = constrain(x, ("batch", "seq", "embed"))

    def body(h, layer_p):
        a, _ = attention_block(layer_p["attn"], L.apply_norm(layer_p["ln1"], h, cfg),
                               cfg, positions=pos, causal=False)
        h = h + a
        h = h + L.apply_mlp(layer_p["mlp"], L.apply_norm(layer_p["ln2"], h, cfg), cfg)
        return constrain(h, ("batch", "seq", "embed")), None

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(params["enc_ln_f"], x, cfg)


def _cross_kv(p, memory, cfg):
    """Precompute cross-attention K/V from encoder memory (per layer)."""
    dt = memory.dtype
    b, s, _ = memory.shape
    k = (memory @ p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (memory @ p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _decode_stack(params, tokens, cfg, memory, caches, *, positions,
                  cache_index, kv_len, train=False):
    dt = dtype_of(cfg)
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg, dt)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(dt)

    def body(h, xs):
        if train:
            layer_p = xs
            layer_c = None
        else:
            layer_p, layer_c = xs
        a, new_c = attention_block(
            layer_p["self_attn"], L.apply_norm(layer_p["ln1"], h, cfg), cfg,
            positions=positions, cache=layer_c, cache_index=cache_index,
            kv_len=kv_len, causal=True)
        h = h + a
        ckv = _cross_kv(layer_p["cross_attn"], memory, cfg)
        a, _ = attention_block(
            layer_p["cross_attn"], L.apply_norm(layer_p["ln_cross"], h, cfg),
            cfg, positions=positions, cross_kv=ckv)
        h = h + a
        h = h + L.apply_mlp(layer_p["mlp"], L.apply_norm(layer_p["ln2"], h, cfg), cfg)
        h = constrain(h, ("batch", "seq", "embed"))
        return h, new_c

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    xs = params["dec_blocks"] if train else (params["dec_blocks"], caches["self"])
    x, new_self = jax.lax.scan(body, x, xs)
    x = L.apply_norm(params["ln_f"], x, cfg)
    if not train:
        caches = dict(caches)
        caches["self"] = new_self
    return x, caches


def cache_specs(cfg, batch: int, cache_len: int):
    dt = dtype_of(cfg)
    one = attn_cache_spec(cfg, batch, cache_len, dt)
    return {
        "self": {k: (jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape, s.dtype),
                     ("layers",) + ax) for k, (s, ax) in one.items()},
        # encoder memory re-used every decode step (cross K/V derive from it)
        "memory": (jax.ShapeDtypeStruct((batch, cfg.enc_len, cfg.d_model), dt),
                   ("batch", "kv_seq", "embed")),
    }


def loss(params, batch, cfg):
    """batch: audio_features (B, enc_len, d), tokens/labels (B, S)."""
    memory = encode(params, batch["audio_features"], cfg)
    x, _ = _decode_stack(params, batch["tokens"], cfg, memory, None,
                         positions=None, cache_index=None, kv_len=None,
                         train=True)
    return L.chunked_cross_entropy(x, params["embed"], batch["labels"], cfg,
                                   loss_mask=batch.get("loss_mask"))


def prefill(params, batch, caches, cfg):
    memory = encode(params, batch["audio_features"], cfg)
    caches = dict(caches)
    caches["memory"] = memory.astype(caches["memory"].dtype)
    x, caches = _decode_stack(params, batch["tokens"], cfg, memory, caches,
                              positions=None, cache_index=jnp.int32(0),
                              kv_len=None)
    return _last_logits(params, x, cfg), caches


def decode(params, tokens, pos, kv_len, caches, cfg):
    b = tokens.shape[0]
    positions = pos.reshape(b, 1).astype(jnp.int32)
    x, caches = _decode_stack(
        params, tokens.reshape(b, 1), cfg, caches["memory"], caches,
        positions=positions, cache_index=pos.astype(jnp.int32),
        kv_len=kv_len.astype(jnp.int32))
    return _last_logits(params, x, cfg), caches


def make_model_def():
    return ModelDef(specs=specs, loss=loss, prefill=prefill, decode=decode,
                    cache_specs=cache_specs)
