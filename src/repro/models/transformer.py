"""Decoder-only transformer LM (dense / GQA / MoE / sliding-window).

Scan-over-layers with remat: block parameters are stacked along a leading
`layers` axis and the stack runs under jax.lax.scan, keeping HLO size O(1)
in depth (essential for 80-layer configs at 512 devices) with full
activation rematerialization in the backward pass.

Serves as the backbone for qwen1.5-110b, mistral-nemo-12b, yi-34b,
codeqwen1.5-7b, moonshot-v1-16b-a3b, granite-moe-3b-a800m, and (via vlm.py /
encdec.py) llava-next and whisper.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.backend import backends_for
from repro.models import layers as L
from repro.models import moe as M
from repro.models.attention import attn_specs, attention_block
from repro.models.module import Param, is_param
from repro.sharding.partitioning import constrain

__all__ = ["ModelDef", "stack_specs", "lm_specs", "lm_hidden", "lm_loss",
           "lm_prefill", "lm_decode", "lm_cache_specs", "lm_page_specs",
           "lm_prefill_paged", "lm_decode_paged", "lm_verify_paged",
           "dtype_of"]


class ModelDef(NamedTuple):
    """Uniform model interface used by the launcher / trainer / server."""

    specs: Callable[..., Any]
    loss: Callable[..., Any]  # (params, batch, cfg) -> (loss, aux)
    prefill: Callable[..., Any]  # (params, batch, cache, cfg) -> (logits, cache)
    decode: Callable[..., Any]  # (params, tokens, pos, kv_len, cache, cfg) -> (logits, cache)
    cache_specs: Callable[..., Any]  # (cfg, batch, cache_len) -> tree of (SDS, axes)
    # Paged-serving interface (None for families without a paged cache):
    # page_specs(cfg, n_pages, page_size, max_batch) -> tree of (SDS, axes)
    # prefill_paged(params, batch{tokens,lens[,offsets]}, pools, table, cfg)
    # decode_paged(params, tokens, pos, kv_len, pools, table, cfg[, base])
    # verify_paged(params, batch, pools, table, cfg) -> ((B,S,V), pools)
    page_specs: Optional[Callable[..., Any]] = None
    prefill_paged: Optional[Callable[..., Any]] = None
    decode_paged: Optional[Callable[..., Any]] = None
    verify_paged: Optional[Callable[..., Any]] = None


def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def stack_specs(specs, n: int):
    """Add a leading `layers` axis of size n to every Param in the tree."""
    return jax.tree.map(
        lambda p: Param((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
        specs,
        is_leaf=is_param,
    )


def _block_specs(cfg):
    s = {"ln1": L.norm_specs(cfg), "attn": attn_specs(cfg), "ln2": L.norm_specs(cfg)}
    s["ffn"] = M.moe_specs(cfg) if cfg.n_experts else L.mlp_specs(cfg)
    return s


def _apply_block(p, x, cfg, *, positions, cache=None, cache_index=None,
                 kv_len=None, page_table=None, scale_base=None, causal=True,
                 backend=None):
    h, new_cache = attention_block(
        p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg,
        positions=positions, cache=cache, cache_index=cache_index,
        kv_len=kv_len, page_table=page_table, scale_base=scale_base,
        causal=causal, backend=backend)
    x = constrain(x + h, ("batch", "res_seq", "embed"))
    ff_in = L.apply_norm(p["ln2"], x, cfg)
    if cfg.n_experts:
        ff, aux = M.apply_moe(p["ffn"], ff_in, cfg)
    else:
        ff, aux = L.apply_mlp(p["ffn"], ff_in, cfg), {}
    x = constrain(x + ff, ("batch", "res_seq", "embed"))
    return x, new_cache, aux


def lm_specs(cfg):
    return {
        "embed": L.embed_specs(cfg),
        "blocks": stack_specs(_block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg),
    }


def _scan_blocks(params, x, cfg, *, positions, caches=None, cache_index=None,
                 kv_len=None, page_table=None, scale_base=None, causal=True):
    """Run the layer stack; returns (x, new_caches, aux_sums).

    Uniform-backend stacks run under jax.lax.scan with layer-stacked
    caches.  A per-layer backend policy (cfg.layer_backends) makes cache
    pytrees heterogeneous across layers, so those stacks unroll: caches
    are a TUPLE of per-layer trees and each layer binds its own backend.
    """
    backends = backends_for(cfg)
    # the same predicate decides cache layout in lm_cache_specs/lm_page_specs
    uniform = cfg.uniform_backend is not None
    per_layer_caches = isinstance(caches, (tuple, list))

    def body(carry, xs, backend=backends[0]):
        h, aux_sum = carry
        layer_p, layer_cache = xs
        if not isinstance(layer_cache, dict):  # train: no cache threaded
            layer_cache = None
        h, new_cache, aux = _apply_block(
            layer_p, h, cfg, positions=positions, cache=layer_cache,
            cache_index=cache_index, kv_len=kv_len, page_table=page_table,
            scale_base=scale_base, causal=causal, backend=backend)
        aux_vec = jnp.stack(
            [aux.get("moe_aux_loss", jnp.float32(0)),
             aux.get("moe_drop_frac", jnp.float32(0))])
        return (h, aux_sum + aux_vec), new_cache

    if cfg.scan_layers and uniform and not per_layer_caches:
        body_fn = body
        if cfg.remat == "full":
            body_fn = jax.checkpoint(body, prevent_cse=False)
        (x, aux_sum), new_caches = jax.lax.scan(
            body_fn, (x, jnp.zeros(2, jnp.float32)), (params["blocks"], caches))
    else:
        aux_sum = jnp.zeros(2, jnp.float32)
        outs = []
        for i in range(cfg.n_layers):
            layer_p = jax.tree.map(lambda a: a[i], params["blocks"])
            if caches is None:
                layer_c = None
            elif per_layer_caches:
                layer_c = caches[i]
            else:
                layer_c = jax.tree.map(lambda a: a[i], caches)
            # bind the layer's backend BEFORE any transform so the object
            # never flows through tracing as a pytree input
            bound = functools.partial(body, backend=backends[i])
            if cfg.remat == "full":
                bound = jax.checkpoint(bound, prevent_cse=False)
            (x, aux_sum), nc = bound((x, aux_sum), (layer_p, layer_c))
            outs.append(nc)
        if caches is None:
            new_caches = None
        elif per_layer_caches:
            new_caches = tuple(outs)
        else:
            new_caches = jax.tree.map(lambda *cs: jnp.stack(cs), *outs)
    aux = {"moe_aux_loss": aux_sum[0] / cfg.n_layers,
           "moe_drop_frac": aux_sum[1] / cfg.n_layers}
    return x, new_caches, aux


def _none_caches(cfg):
    """A scan-compatible stand-in when no cache is threaded (train)."""
    return jnp.zeros((cfg.n_layers, 0), jnp.float32)


def lm_hidden(params, tokens, cfg, *, positions=None, caches=None,
              cache_index=None, kv_len=None, page_table=None,
              scale_base=None, causal=True, prefix_embeds=None):
    """tokens (B, S) -> final hidden states (B, S[+P], d)."""
    dt = dtype_of(cfg)
    x = L.embed_lookup(params["embed"], tokens, cfg, dt)
    if prefix_embeds is not None:  # VLM: prepend patch embeddings
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    x = constrain(x, ("batch", "res_seq", "embed"))
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if getattr(cfg, "abs_pos", None) == "sinusoidal" or not getattr(cfg, "use_rope", True):
        x = x + L.sinusoidal_positions(positions, cfg.d_model).astype(dt)
    if caches is None:
        caches = _none_caches(cfg)
    x, new_caches, aux = _scan_blocks(
        params, x, cfg, positions=positions, caches=caches,
        cache_index=cache_index, kv_len=kv_len, page_table=page_table,
        scale_base=scale_base, causal=causal)
    x = L.apply_norm(params["ln_f"], x, cfg)
    # loss/head consumers slice along seq: hand them a seq-replicated copy
    x = constrain(x, ("batch", None, "embed"))
    return x, new_caches, aux


def lm_loss(params, batch, cfg):
    """Causal LM loss. batch: tokens (B,S), labels (B,S), [loss_mask]."""
    x, _, aux = lm_hidden(params, batch["tokens"], cfg)
    loss, stats = L.chunked_cross_entropy(
        x, params["embed"], batch["labels"], cfg,
        loss_mask=batch.get("loss_mask"))
    if cfg.n_experts:
        loss = loss + 0.01 * aux["moe_aux_loss"]
    stats.update(aux)
    return loss, stats


def _stack_layer_specs(cfg, one):
    """Add the leading `layers` axis to a single-layer spec tree."""
    return {
        k: (jax.ShapeDtypeStruct((cfg.n_layers,) + sds.shape, sds.dtype),
            ("layers",) + axes)
        for k, (sds, axes) in one.items()
    }


def lm_cache_specs(cfg, batch: int, cache_len: int):
    """Cache specs: layer-stacked (scan-compatible) for a uniform backend;
    a TUPLE of per-layer spec trees under a mixed layer_backends policy
    (layouts differ per layer, so the stack unrolls)."""
    dt = dtype_of(cfg)
    bks = backends_for(cfg)
    if cfg.uniform_backend is not None:
        return _stack_layer_specs(cfg, bks[0].cache_spec(cfg, batch,
                                                         cache_len, dt))
    return tuple(bk.cache_spec(cfg, batch, cache_len, dt) for bk in bks)


def lm_prefill(params, batch, caches, cfg):
    """Prefill: forward writing the cache at index 0.

    With cfg.prefill_chunk set, the prompt is processed in chunks that
    attend to the cache-so-far (activation memory bounded by the chunk —
    the standard chunked-prefill serving technique).

    Returns (last-token logits (B, V), caches)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    chunk = cfg.prefill_chunk
    if (chunk and s > chunk and s % chunk == 0
            and batch.get("image_embeds") is None):
        n = s // chunk
        toks = tokens.reshape(b, n, chunk).swapaxes(0, 1)  # (n, B, chunk)

        def body(carry, xs):
            cs, _ = carry
            i, tk = xs
            pos = (i * chunk
                   + jnp.arange(chunk, dtype=jnp.int32))[None].repeat(b, 0)
            kvl = jnp.full((b,), (i + 1) * chunk, jnp.int32)
            x, cs, _ = lm_hidden(
                params, tk, cfg, positions=pos, caches=cs,
                cache_index=(i * chunk).astype(jnp.int32), kv_len=kvl,
                causal=True)
            return (cs, x[:, -1]), None

        dt = dtype_of(cfg)
        init = (caches, jnp.zeros((b, cfg.d_model), dt))
        (caches, last), _ = jax.lax.scan(
            body, init, (jnp.arange(n, dtype=jnp.int32), toks))
        logits = _last_logits(params, last[:, None], cfg)
        return logits, caches

    x, caches, _ = lm_hidden(
        params, tokens, cfg, caches=caches, cache_index=jnp.int32(0),
        kv_len=None, causal=True,
        prefix_embeds=batch.get("image_embeds"))
    logits = _last_logits(params, x, cfg)
    return logits, caches


def lm_page_specs(cfg, n_pages: int, page_size: int, max_batch: int):
    """Paged-pool specs (serving/kv_cache.py layout): layer-stacked for a
    uniform backend, per-layer tuple under a mixed policy — dense bf16
    pages and bit-packed CAM pages then live side by side in one pool."""
    dt = dtype_of(cfg)
    bks = backends_for(cfg)
    if cfg.uniform_backend is not None:
        return _stack_layer_specs(cfg, bks[0].page_spec(cfg, n_pages,
                                                        page_size, max_batch,
                                                        dt))
    return tuple(bk.page_spec(cfg, n_pages, page_size, max_batch, dt)
                 for bk in bks)


def _paged_suffix_hidden(params, batch, caches, page_table, cfg):
    """Shared hidden path of the paged Sq>1 seam (prefill + verify).

    batch: tokens (B, S) right-padded suffixes at positions
    ``offsets + arange(S)``, lens (B,) TOTAL valid lengths, optional
    offsets / scale_base — see ``lm_prefill_paged``.  Returns the full
    per-position hidden states (x (B, S, d), pools).
    """
    tokens, lens = batch["tokens"], batch["lens"].astype(jnp.int32)
    b, s = tokens.shape
    offsets = batch.get("offsets")
    offsets = (jnp.zeros((b,), jnp.int32) if offsets is None
               else offsets.astype(jnp.int32))
    scale_base = batch.get("scale_base")
    scale_base = (offsets if scale_base is None
                  else scale_base.astype(jnp.int32))
    chunk = cfg.prefill_chunk
    if chunk and s > chunk and s % chunk == 0:
        n = s // chunk
        toks = tokens.reshape(b, n, chunk).swapaxes(0, 1)  # (n, B, chunk)

        def body(cs, xs):
            i, tk = xs
            pos = (offsets[:, None] + i * chunk
                   + jnp.arange(chunk, dtype=jnp.int32)[None])
            x, cs, _ = lm_hidden(
                params, tk, cfg, positions=pos, caches=cs, kv_len=lens,
                page_table=page_table, scale_base=scale_base, causal=True)
            return cs, x

        caches, xs = jax.lax.scan(
            body, caches, (jnp.arange(n, dtype=jnp.int32), toks))
        x = xs.swapaxes(0, 1).reshape(b, s, -1)  # (B, S, d)
    else:
        pos = offsets[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        x, caches, _ = lm_hidden(
            params, tokens, cfg, positions=pos, caches=caches, kv_len=lens,
            page_table=page_table, scale_base=scale_base, causal=True)
    return x, caches


def lm_prefill_paged(params, batch, caches, page_table, cfg):
    """Batched prefill into the paged cache.

    batch: tokens (B, S) right-padded prompt SUFFIXES, lens (B,) TOTAL
    valid lengths (lens == 0 marks an inactive slot whose page-table row
    must point at the trash page), and optional offsets (B,) — each
    slot's first computed position.  A nonzero offset means positions
    [0, offset) live in already-written pages — a copy-on-write shared
    prefix, or (continuous batching) this slot's OWN earlier prefill
    chunks: the slot's tokens are the suffix starting at ``offset``,
    attending through the page table to the earlier rows.  Optional
    ``scale_base`` (B,) separates the per-slot running-statistics origin
    from the chunk offset: positions >= scale_base were computed by THIS
    slot (they count toward camformer's k_scale running mean across
    chunks), positions below it live in another slot's shared pages.  It
    defaults to ``offsets`` (single-dispatch prefill, where the two
    coincide).  With cfg.prefill_chunk set and S a chunk multiple, the
    suffix batch is processed in chunks that attend to the pages written
    so far (chunked prefill, activation memory bounded by the chunk).
    Returns (per-slot last-suffix-token logits (B, V), pools).
    """
    lens = batch["lens"].astype(jnp.int32)
    offsets = batch.get("offsets")
    offsets = (jnp.zeros(lens.shape, jnp.int32) if offsets is None
               else offsets.astype(jnp.int32))
    x, caches = _paged_suffix_hidden(params, batch, caches, page_table, cfg)
    # the final valid token sits at suffix row (lens - offsets - 1)
    last = jnp.take_along_axis(
        x, jnp.clip(lens - offsets - 1, 0, x.shape[1] - 1)[
            :, None, None].astype(jnp.int32),
        axis=1)[:, 0]
    return _head_logits(params, last, cfg), caches


def lm_verify_paged(params, batch, caches, page_table, cfg):
    """Speculative-decode verification over the paged Sq>1 seam.

    Identical contract to ``lm_prefill_paged`` but returns the logits of
    EVERY suffix position — (B, S, V) — so the engine can score all k+1
    speculative positions in one fused step (row j holds the target
    distribution for the token AFTER input position offsets + j).

    The pass runs under ``spec_verify`` semantics: stateful backends use
    per-query running ``k_scale`` (each chunk column sees exactly the
    scale the sequential loop would have used at its position) and stash
    the chunk's key means for exact rollback.  The chunk is k+1 tokens,
    so it never needs ``prefill_chunk`` slicing.
    """
    cfg = cfg.replace(spec_verify=True, prefill_chunk=0)
    x, caches = _paged_suffix_hidden(params, batch, caches, page_table, cfg)
    return _all_logits(params, x, cfg), caches


def lm_decode_paged(params, tokens, pos, kv_len, caches, page_table, cfg,
                    base=None):
    """One decode step against the paged cache. tokens/pos/kv_len: (B,);
    base: (B,) per-slot prefix-sharing offset (see lm_prefill_paged)."""
    b = tokens.shape[0]
    positions = pos.reshape(b, 1).astype(jnp.int32)
    x, caches, _ = lm_hidden(
        params, tokens.reshape(b, 1), cfg, positions=positions,
        caches=caches, kv_len=kv_len.astype(jnp.int32),
        page_table=page_table, scale_base=base, causal=True)
    return _last_logits(params, x, cfg), caches


def lm_decode(params, tokens, pos, kv_len, caches, cfg):
    """One decode step. tokens (B,), pos (B,), kv_len (B,).

    Returns (logits (B, V), updated caches)."""
    b = tokens.shape[0]
    positions = pos.reshape(b, 1).astype(jnp.int32)
    x, caches, _ = lm_hidden(
        params, tokens.reshape(b, 1), cfg, positions=positions,
        caches=caches, cache_index=pos.astype(jnp.int32),
        kv_len=kv_len.astype(jnp.int32), causal=True)
    logits = _last_logits(params, x, cfg)
    return logits, caches


def _last_logits(params, x, cfg):
    return _head_logits(params, x[:, -1], cfg)


def _all_logits(params, x, cfg):
    """Vocabulary logits for every position of x (B, S, d) -> (B, S, V)."""
    dt = x.dtype
    if cfg.tie_embeddings:
        head = params["embed"]["tok"].astype(dt).T
    else:
        head = params["embed"]["head"].astype(dt)
    logits = x @ head
    logits = constrain(logits, ("batch", None, "vocab")).astype(jnp.float32)
    if logits.shape[-1] > cfg.vocab:  # vocab-padding columns never sampled
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e9)
    return logits


def _head_logits(params, last, cfg):
    """Vocabulary logits for per-slot final hidden states last (B, d)."""
    dt = last.dtype
    if cfg.tie_embeddings:
        head = params["embed"]["tok"].astype(dt).T
    else:
        head = params["embed"]["head"].astype(dt)
    logits = last @ head
    logits = constrain(logits, ("batch", "vocab")).astype(jnp.float32)
    if logits.shape[-1] > cfg.vocab:  # vocab-padding columns never sampled
        logits = jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab,
                           logits, -1e9)
    return logits


def make_model_def():
    return ModelDef(
        specs=lm_specs,
        loss=lm_loss,
        prefill=lm_prefill,
        decode=lm_decode,
        cache_specs=lm_cache_specs,
        page_specs=lm_page_specs,
        prefill_paged=lm_prefill_paged,
        decode_paged=lm_decode_paged,
        verify_paged=lm_verify_paged,
    )
