"""Model zoo: one backbone per assigned architecture family."""

from repro.models.registry import get_model_def  # noqa: F401
from repro.models.transformer import ModelDef  # noqa: F401
