"""LLaVA-Next-style VLM: Mistral-7B language backbone + vision stub.

Per the assignment the vision tower / anyres tiling is a STUB:
`input_specs()` supplies precomputed patch embeddings (B, n_patches,
d_model) which are prepended to the text sequence.  The backbone (and
CAMformer attention over the mixed sequence) is the real system under test.
"""

from __future__ import annotations


from repro.models import layers as L
from repro.models import transformer as T

__all__ = ["make_model_def"]


def loss(params, batch, cfg):
    """batch: image_embeds (B, P, d), tokens (B, S_text), labels (B, S_text)."""
    img = batch["image_embeds"]
    p = img.shape[1]
    x, _, aux = T.lm_hidden(params, batch["tokens"], cfg, prefix_embeds=img)
    # hidden at absolute position P-1+i predicts text token i -> text-aligned
    # slice starts at the last image slot
    x_text = x[:, p - 1 : -1] if x.shape[1] > p else x
    loss_val, stats = L.chunked_cross_entropy(
        x_text, params["embed"], batch["labels"][:, : x_text.shape[1]], cfg,
        loss_mask=batch.get("loss_mask"))
    stats.update(aux)
    return loss_val, stats


def prefill(params, batch, caches, cfg):
    return T.lm_prefill(params, batch, caches, cfg)


def make_model_def():
    return T.ModelDef(
        specs=T.lm_specs,
        loss=loss,
        prefill=prefill,
        decode=T.lm_decode,
        cache_specs=T.lm_cache_specs,
        # text-only serving: the backbone pages exactly like the LM
        page_specs=T.lm_page_specs,
        prefill_paged=T.lm_prefill_paged,
        decode_paged=T.lm_decode_paged,
    )
