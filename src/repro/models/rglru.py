"""RecurrentGemma / Griffin hybrid (arXiv:2402.19427): RG-LRU recurrent
blocks and local (sliding-window, MQA) attention in a 2:1 pattern
(rec, rec, attn).  Sub-quadratic by construction: the recurrent state is
O(1) and the attention cache is bounded by the window — this arch runs
long_500k natively.

CAMformer applicability: the technique applies to the 1-in-3 local-attention
layers (binary top-k over a window-bounded cache); RG-LRU layers are
attention-free (DESIGN.md §Arch-applicability).

Scan layout: 26 layers = 8 periods of (rec, rec, attn) under lax.scan + 2
trailing rec layers unrolled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attn_cache_spec, attn_specs, attention_block
from repro.models.module import Param
from repro.models.transformer import ModelDef, _last_logits, dtype_of, stack_specs
from repro.sharding.partitioning import constrain

__all__ = ["make_model_def"]

RG_C = 8.0  # RG-LRU decay sharpness constant


def _rec_specs(cfg):
    d, r = cfg.d_model, cfg.rnn_width
    w = cfg.conv_width
    return {
        "ln": L.norm_specs(cfg),
        "w_gate": Param((d, r), ("embed", "rnn")),
        "w_x": Param((d, r), ("embed", "rnn")),
        "conv_w": Param((w, r), ("conv", "rnn")),
        "conv_b": Param((r,), (None,), init="zeros"),
        "w_rg": Param((r, r), ("rnn", "rnn"), scale=r**-0.5),
        "b_rg": Param((r,), (None,), init="zeros"),
        "w_ig": Param((r, r), ("rnn", "rnn"), scale=r**-0.5),
        "b_ig": Param((r,), (None,), init="zeros"),
        "lam": Param((r,), (None,), init="ones"),  # softplus(lam) decay rates
        "w_out": Param((r, d), ("rnn", "embed")),
        "ln_mlp": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _attn_layer_specs(cfg):
    return {
        "ln": L.norm_specs(cfg),
        "attn": attn_specs(cfg),
        "ln_mlp": L.norm_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def _layout(cfg):
    period = len(cfg.layer_pattern)  # ("rglru","rglru","attn")
    n_periods = cfg.n_layers // period
    tail = cfg.n_layers - n_periods * period  # trailing layers, pattern order
    return period, n_periods, tail


def specs(cfg):
    _, n_periods, tail = _layout(cfg)
    s = {
        "embed": L.embed_specs(cfg),
        "rec1": stack_specs(_rec_specs(cfg), n_periods),
        "rec2": stack_specs(_rec_specs(cfg), n_periods),
        "attn": stack_specs(_attn_layer_specs(cfg), n_periods),
        "ln_f": L.norm_specs(cfg),
    }
    for i in range(tail):
        s[f"tail_rec{i+1}"] = _rec_specs(cfg)
    return s


# ---------------- RG-LRU recurrent block ----------------

def _causal_conv(x, conv_state, w, b):
    """Depthwise causal conv over time. x: (B,S,r); conv_state: (B,W-1,r)."""
    width = w.shape[0]
    xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(
        xx[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(width)
    ) + b.astype(x.dtype)
    new_state = xx[:, -(width - 1) :] if width > 1 else conv_state
    return out, new_state


def _apply_rec(p, x, cfg, cache):
    """One Griffin recurrent block (+MLP). cache: {"conv": (B,W-1,r), "h": (B,r)}."""
    dt = x.dtype
    h_in = L.apply_norm(p["ln"], x, cfg)
    gate = jax.nn.gelu(h_in @ p["w_gate"].astype(dt))
    u = h_in @ p["w_x"].astype(dt)
    u = constrain(u, ("batch", "seq", "rnn"))
    u, conv_state = _causal_conv(u, cache["conv"], p["conv_w"], p["conv_b"])

    r_g = jax.nn.sigmoid(u @ p["w_rg"].astype(dt) + p["b_rg"].astype(dt))
    i_g = jax.nn.sigmoid(u @ p["w_ig"].astype(dt) + p["b_ig"].astype(dt))
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r_g.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i_g * u).astype(jnp.float32)
    drive = jnp.sqrt(jnp.maximum(1.0 - a**2, 1e-12)) * gated

    def step(h, inp):
        a_t, d_t = inp
        h = a_t * h + d_t
        return h, h

    a_s = a.swapaxes(0, 1)  # (S,B,r)
    d_s = drive.swapaxes(0, 1)
    h_last, ys = jax.lax.scan(step, cache["h"].astype(jnp.float32), (a_s, d_s))
    y = ys.swapaxes(0, 1).astype(dt)

    out = (gate * y) @ p["w_out"].astype(dt)
    x = x + out
    x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln_mlp"], x, cfg), cfg)
    x = constrain(x, ("batch", "seq", "embed"))
    return x, {"conv": conv_state.astype(cache["conv"].dtype),
               "h": h_last.astype(cache["h"].dtype)}


def _apply_attn(p, x, cfg, cache, positions, cache_index, kv_len,
                kv_positions=None):
    h, new_cache = attention_block(
        p["attn"], L.apply_norm(p["ln"], x, cfg), cfg,
        positions=positions, cache=cache, cache_index=cache_index,
        kv_len=kv_len, kv_positions=kv_positions, causal=True,
        window=cfg.window)
    x = x + h
    x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln_mlp"], x, cfg), cfg)
    return constrain(x, ("batch", "seq", "embed")), new_cache


# ---------------- caches ----------------

def _rec_cache_spec(cfg, batch, n: int):
    r, w = cfg.rnn_width, cfg.conv_width
    return {
        "conv": (jax.ShapeDtypeStruct((n, batch, w - 1, r), jnp.float32),
                 ("layers", "batch", "conv", "rnn")),
        "h": (jax.ShapeDtypeStruct((n, batch, r), jnp.float32),
              ("layers", "batch", "rnn")),
    }


def cache_specs(cfg, batch: int, cache_len: int):
    """Attention caches are window-bounded (ring buffer); rec state is O(1)."""
    _, n_periods, tail = _layout(cfg)
    wlen = min(cache_len, cfg.window or cache_len)
    attn_one = attn_cache_spec(cfg, batch, wlen, dtype_of(cfg))
    out = {
        "rec1": _rec_cache_spec(cfg, batch, n_periods),
        "rec2": _rec_cache_spec(cfg, batch, n_periods),
        "attn": {
            k: (jax.ShapeDtypeStruct((n_periods,) + sds.shape, sds.dtype),
                ("layers",) + axes)
            for k, (sds, axes) in attn_one.items()
        },
        "attn_pos": (jax.ShapeDtypeStruct((batch, wlen), jnp.int32),
                     ("batch", "kv_seq")),
    }
    for i in range(tail):
        out[f"tail_rec{i+1}"] = {
            k: (jax.ShapeDtypeStruct(sds.shape[1:], sds.dtype), axes[1:])
            for k, (sds, axes) in _rec_cache_spec(cfg, batch, 1).items()
        }
    return out


def _zero_caches(cfg, batch, cache_len):
    def mk(t):
        sds = t[0]
        z = jnp.zeros(sds.shape, sds.dtype)
        return z
    tree = jax.tree.map(mk, cache_specs(cfg, batch, cache_len),
                        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                        and isinstance(x[0], jax.ShapeDtypeStruct))
    return tree


# ---------------- forward ----------------

def _forward(params, tokens, cfg, caches, *, positions, cache_index, kv_len,
             kv_positions=None, train=False):
    dt = dtype_of(cfg)
    b, s = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg, dt) * jnp.asarray(
        cfg.d_model**0.5, dt)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, xs):
        h = carry
        if train:
            p1, c1, p2, c2, pa = xs
            ca = None
        else:
            p1, c1, p2, c2, pa, ca = xs
        h, nc1 = _apply_rec(p1, h, cfg, c1)
        h, nc2 = _apply_rec(p2, h, cfg, c2)
        h, nca = _apply_attn(pa, h, cfg, ca, positions, cache_index, kv_len,
                             kv_positions)
        return h, (nc1, nc2, nca) if not train else (nc1, nc2)

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)

    if train:
        xs = (params["rec1"], caches["rec1"], params["rec2"], caches["rec2"],
              params["attn"])
        x, _ = jax.lax.scan(body, x, xs)
        new_caches = caches
    else:
        xs = (params["rec1"], caches["rec1"], params["rec2"], caches["rec2"],
              params["attn"], caches["attn"])
        x, (nc1, nc2, nca) = jax.lax.scan(body, x, xs)
        new_caches = dict(caches)
        new_caches.update({"rec1": nc1, "rec2": nc2, "attn": nca})

    _, _, tail = _layout(cfg)
    for i in range(tail):
        key = f"tail_rec{i+1}"
        x, nc = _apply_rec(params[key], x, cfg, caches[key])
        if not train:
            new_caches[key] = nc
    return L.apply_norm(params["ln_f"], x, cfg), new_caches


def loss(params, batch, cfg):
    b, s = batch["tokens"].shape
    caches = _zero_caches(cfg, b, s)
    x, _ = _forward(params, batch["tokens"], cfg, caches,
                    positions=None, cache_index=None, kv_len=None, train=True)
    return L.chunked_cross_entropy(x, params["embed"], batch["labels"], cfg,
                                   loss_mask=batch.get("loss_mask"))


def prefill(params, batch, caches, cfg):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x, caches = _forward(params, tokens, cfg, caches,
                         positions=None, cache_index=jnp.int32(0), kv_len=None)
    wlen = caches["attn"]["v"].shape[3]
    caches = dict(caches)
    if s >= wlen:  # ring holds the trailing window (written by _write_cache)
        pos0 = jnp.arange(s - wlen, s, dtype=jnp.int32)
    else:  # slots >= s are unwritten; kv_len masking excludes them
        pos0 = jnp.arange(wlen, dtype=jnp.int32)
    caches["attn_pos"] = jnp.broadcast_to(pos0[None], (b, wlen))
    return _last_logits(params, x, cfg), caches


def decode(params, tokens, pos, kv_len, caches, cfg):
    b = tokens.shape[0]
    positions = pos.reshape(b, 1).astype(jnp.int32)
    wlen = caches["attn"]["v"].shape[3]
    slots = jnp.mod(pos, wlen).astype(jnp.int32)  # per-slot ring position
    caches = dict(caches)
    caches["attn_pos"] = jax.vmap(
        lambda row, val, s: jax.lax.dynamic_update_slice(row, val, (s,))
    )(caches["attn_pos"], positions, slots)
    x, caches = _forward(params, tokens.reshape(b, 1), cfg, caches,
                         positions=positions, cache_index=slots,
                         kv_len=kv_len.astype(jnp.int32),
                         kv_positions=caches["attn_pos"])
    return _last_logits(params, x, cfg), caches


def make_model_def():
    return ModelDef(specs=specs, loss=loss, prefill=prefill, decode=decode,
                    cache_specs=cache_specs)
