"""Model registry: arch family -> ModelDef."""

from __future__ import annotations

from repro.models import encdec, rglru, rwkv6, transformer, vlm
from repro.models.transformer import ModelDef

__all__ = ["get_model_def"]

_FAMILY = {
    "dense": transformer.make_model_def,
    "moe": transformer.make_model_def,
    "ssm": rwkv6.make_model_def,
    "hybrid": rglru.make_model_def,
    "encdec": encdec.make_model_def,
    "audio": encdec.make_model_def,
    "vlm": vlm.make_model_def,
}


def get_model_def(cfg) -> ModelDef:
    try:
        return _FAMILY[cfg.family]()
    except KeyError:
        raise KeyError(f"no model family {cfg.family!r}; have {sorted(_FAMILY)}")
