"""Shared model layers: norms, RoPE, MLPs, embeddings, chunked CE loss."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import Param
from repro.sharding.partitioning import constrain

__all__ = [
    "norm_specs", "apply_norm", "rope", "sinusoidal_positions",
    "mlp_specs", "apply_mlp", "embed_specs", "embed_lookup",
    "chunked_cross_entropy",
]


# ---------------- norms ----------------

def norm_specs(cfg, with_bias: bool | None = None):
    with_bias = cfg.norm == "layer" if with_bias is None else with_bias
    s = {"scale": Param((cfg.d_model,), (None,), init="ones")}
    if with_bias:
        s["bias"] = Param((cfg.d_model,), (None,), init="zeros")
    return s


def apply_norm(p, x, cfg, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layer":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:  # rms
        var = (xf**2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------- positions ----------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, H, S, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d: int) -> jax.Array:
    """Whisper-style sinusoidal absolute embeddings. (B,S) -> (B,S,d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------- MLP ----------------

def mlp_specs(cfg, d_ff: int | None = None):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    if cfg.act in ("silu", "geglu"):  # gated
        return {
            "w_gate": Param((d, d_ff), ("embed", "mlp")),
            "w_up": Param((d, d_ff), ("embed", "mlp")),
            "w_down": Param((d_ff, d), ("mlp", "embed")),
        }
    return {  # plain 2-layer (whisper)
        "w_in": Param((d, d_ff), ("embed", "mlp")),
        "b_in": Param((d_ff,), (None,), init="zeros"),
        "w_out": Param((d_ff, d), ("mlp", "embed")),
        "b_out": Param((d,), (None,), init="zeros"),
    }


def apply_mlp(p, x, cfg):
    dt = x.dtype
    if "w_gate" in p:
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        g = constrain(g, ("batch", "seq", "mlp"))
        act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
        return (act * u) @ p["w_down"].astype(dt)
    h = x @ p["w_in"].astype(dt) + p["b_in"].astype(dt)
    h = constrain(jax.nn.gelu(h), ("batch", "seq", "mlp"))
    return h @ p["w_out"].astype(dt) + p["b_out"].astype(dt)


# ---------------- embeddings / head ----------------

def embed_specs(cfg):
    v = cfg.padded_vocab  # pad columns are masked out of every logit
    s = {"tok": Param((v, cfg.d_model), ("vocab", "embed"), init="embed",
                      scale=cfg.d_model**-0.5)}
    if not cfg.tie_embeddings:
        s["head"] = Param((cfg.d_model, v), ("embed", "vocab"))
    return s


def embed_lookup(p, tokens, cfg, dtype):
    e = jnp.take(p["tok"].astype(dtype), tokens, axis=0)
    return constrain(e, ("batch", "seq", "embed"))


def _head_matrix(embed_params, cfg, dtype):
    if cfg.tie_embeddings:
        return embed_params["tok"].astype(dtype).T
    return embed_params["head"].astype(dtype)


def chunked_cross_entropy(
    x: jax.Array,
    embed_params,
    labels: jax.Array,
    cfg,
    *,
    loss_mask: jax.Array | None = None,
    chunk: int = 512,
    z_loss: float = 1e-4,
):
    """Mean CE without materializing full (B, S, V) fp32 logits.

    Scans over sequence chunks; per-chunk logits are vocab-sharded.  Returns
    (loss, aux dict).  x: (B, S, d); labels: (B, S) int32.
    """
    b, s, d = x.shape
    head = _head_matrix(embed_params, cfg, x.dtype)
    if loss_mask is None:
        loss_mask = jnp.ones((b, s), jnp.float32)
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk

    def chunk_loss(xc, yc, mc):
        logits = xc @ head  # (B, c, V_padded)
        logits = constrain(logits, ("batch", "seq", "vocab")).astype(jnp.float32)
        if logits.shape[-1] > cfg.vocab:  # mask vocab-padding columns
            pad_ok = jnp.arange(logits.shape[-1]) < cfg.vocab
            logits = jnp.where(pad_ok, logits, -1e9)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        ce = (lse - gold) * mc
        zl = z_loss * (lse**2) * mc
        return ce.sum() + zl.sum(), (ce.sum(), mc.sum())

    def body(carry, inputs):
        tot, ce_tot, cnt = carry
        xc, yc, mc = inputs
        cl, (ce, n) = chunk_loss(xc, yc, mc)
        return (tot + cl, ce_tot + ce, cnt + n), None

    xs = (
        x[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, d).swapaxes(0, 1),
        labels[:, : n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1),
        loss_mask[:, : n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1),
    )
    (tot, ce_tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0, 0.0), xs)
    if rem:
        cl, (ce, n) = chunk_loss(x[:, -rem:], labels[:, -rem:], loss_mask[:, -rem:])
        tot, ce_tot, cnt = tot + cl, ce_tot + ce, cnt + n
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, {"ce": ce_tot / cnt, "tokens": cnt}
