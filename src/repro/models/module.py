"""Minimal functional parameter-spec system (no flax dependency).

A model is (param_specs(cfg) -> tree of Param, apply(params, ...)).  Param
records shape, dtype-agnostic init, and *logical axis names* used by
sharding/partitioning.py to derive PartitionSpecs — the MaxText
logical-axis-rules pattern, reduced to its essentials.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Param", "is_param", "init_params", "param_shapes", "tree_axes", "count_params"]


@dataclasses.dataclass(frozen=True)
class Param:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == ndim
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override (default: fan-in scaled)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} don't match shape {self.shape}")


def is_param(x: Any) -> bool:
    return isinstance(x, Param)


def _leaf_key(path) -> int:
    s = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return int.from_bytes(hashlib.md5(s.encode()).digest()[:4], "little")


def _init_leaf(p: Param, key: jax.Array, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        std = p.scale or 1.0
        return (jax.random.normal(key, p.shape) * std).astype(dtype)
    # fan-in scaled normal over the last-but-one axis (in-features)
    fan_in = p.shape[0] if len(p.shape) == 1 else p.shape[-2]
    std = p.scale if p.scale is not None else (1.0 / max(1, fan_in)) ** 0.5
    return (jax.random.normal(key, p.shape) * std).astype(dtype)


def init_params(specs, key: jax.Array, dtype=jnp.float32):
    """Initialize a spec tree into arrays with per-leaf derived keys."""

    def f(path, p):
        return _init_leaf(p, jax.random.fold_in(key, _leaf_key(path)), dtype)

    return jax.tree_util.tree_map_with_path(f, specs, is_leaf=is_param)


def param_shapes(specs, dtype=jnp.float32):
    """Spec tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), specs, is_leaf=is_param
    )


def tree_axes(specs):
    """Spec tree -> logical-axes tree (same structure, tuples as leaves)."""
    return jax.tree.map(lambda p: p.axes, specs, is_leaf=is_param)


def count_params(specs) -> int:
    import math

    leaves = jax.tree.leaves(specs, is_leaf=is_param)
    return sum(math.prod(p.shape) for p in leaves)
