"""Token-choice top-k Mixture-of-Experts FFN (expert parallel).

Group-wise einsum dispatch (Mesh-TF / Switch style, tuned for GSPMD):
tokens are split into small contiguous groups of `MOE_GROUP` tokens; within
each group, every routing slot places tokens into a per-expert capacity
buffer via a one-hot dispatch tensor (group, token, expert, capacity).  All
group-indexed tensors stay batch-sharded, so dispatch/combine are entirely
LOCAL einsums — no scatter ops for GSPMD to mangle, no extra collectives.

The dispatch einsum costs g_t * E * C_g * d MACs per group; with small
groups (64 tokens) C_g = g_t*k/E*cf stays tiny and dispatch overhead is
2-4% of expert FLOPs (napkin math in EXPERIMENTS.md §Perf).  Tokens beyond
a group's per-expert capacity are dropped (counted); the usual Switch
load-balancing aux loss is returned.

Expert weights carry ("experts", "embed", "expert_mlp") logical axes ->
expert-parallel over `model` when divisible (moonlight: 64e/16), with the
partitioning fallback sharding d_ff instead when not (granite: 40e).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.module import Param
from repro.sharding.partitioning import constrain

__all__ = ["moe_specs", "apply_moe", "MOE_GROUP"]

MOE_GROUP = 64  # tokens per dispatch group


def moe_specs(cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.padded_experts
    return {
        "router": Param((d, e), ("embed", "experts"), scale=d**-0.5),
        "w_gate": Param((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_up": Param((e, d, f), ("experts", "embed", "expert_mlp")),
        "w_down": Param((e, f, d), ("experts", "expert_mlp", "embed")),
    }


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (B, S, d), aux dict (load-balance loss, drop frac)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    ep = cfg.padded_experts  # dummy experts: masked in routing, sharded in EP
    dt = x.dtype
    t = b * s
    gt = min(MOE_GROUP, t)  # tokens per group
    assert t % gt == 0, (t, gt)
    g = t // gt
    cap = max(1, int(-(-gt * k * cfg.capacity_factor // e)))  # ceil

    xg = x.reshape(g, gt, d)
    xg = constrain(xg, ("batch", None, "embed"))

    logits = xg.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (g,gt,ep)
    if ep > e:  # padded experts never routed to
        logits = jnp.where(jnp.arange(ep) < e, logits, -1e9)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (g,gt,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch load-balancing aux loss over all tokens
    density = jnp.mean(jax.nn.one_hot(eidx[..., 0], ep, dtype=jnp.float32),
                       axis=(0, 1))
    aux_loss = e * jnp.sum(density * probs.mean(axis=(0, 1)))

    # build dispatch (bool-ish) and combine (gated) tensors slot by slot
    disp = jnp.zeros((g, gt, ep, cap), dt)
    comb = jnp.zeros((g, gt, ep, cap), jnp.float32)
    # running per-(group, expert) fill count across slots
    fill = jnp.zeros((g, ep), jnp.int32)
    dropped = 0.0
    for slot in range(k):  # static unroll (k <= 8)
        oh_e = jax.nn.one_hot(eidx[..., slot], ep, dtype=jnp.int32)  # (g,gt,ep)
        # position within expert buffer = prior fill + prefix count in slot
        pos_in_slot = jnp.cumsum(oh_e, axis=1) - oh_e
        pos = pos_in_slot + fill[:, None, :]
        fill = fill + oh_e.sum(axis=1)
        keep = (pos < cap) & (oh_e > 0)
        dropped += 1.0 - (keep.sum() / jnp.maximum(oh_e.sum(), 1)).astype(jnp.float32)
        pos_c = jnp.clip(pos, 0, cap - 1)
        oh_c = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32) * keep[..., None]
        disp = disp + (oh_c).astype(dt)  # (g,gt,e,cap)
        comb = comb + oh_c * gates[..., slot][..., None, None]

    # dispatch: (g,gt,e,cap) x (g,gt,d) -> (g,e,cap,d)   [local einsum]
    buf = jnp.einsum("gtec,gtd->gecd", disp, xg)
    buf = constrain(buf, ("batch", "experts", "capacity", "embed"))

    gte = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    gte = constrain(gte, ("batch", "experts", "capacity", "expert_mlp"))
    h = jax.nn.silu(gte) * up
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    y = constrain(y, ("batch", "experts", "capacity", "embed"))

    out = jnp.einsum("gtec,gecd->gtd", comb.astype(dt), y)
    aux = {"moe_aux_loss": aux_loss, "moe_drop_frac": dropped / k}
    return out.reshape(b, s, d), aux
