"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
decay.  CAMformer's attention technique is INAPPLICABLE here (no QK^T, no KV
cache) — recorded in DESIGN.md §Arch-applicability; the arch is built
without it, which also makes it the native long_500k (sub-quadratic) arch.

Per layer: time-mix (WKV with per-channel data-dependent decay w_t, bonus u)
and channel-mix.  State per layer/head: S in R^{c x c}; decode carries
(token_shift x_prev, S) — O(1) per token.

Faithful-lite simplifications (documented): the 5 token-shift mixes use
static learned mu (the v6 LoRA delta on the decay is kept, as it is the
Finch contribution); head layout (H = d_model / 64) matches the release.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.module import Param
from repro.models.transformer import ModelDef, dtype_of, stack_specs
from repro.sharding.partitioning import constrain

__all__ = ["make_model_def"]


def _heads(cfg):
    return cfg.d_model // cfg.rwkv_head_dim


def _tm_specs(cfg):
    d = cfg.d_model
    c = cfg.rwkv_head_dim
    h = _heads(cfg)
    lora = 64
    return {
        "mu": Param((5, d), (None, None)),  # shift mixes for r,k,v,w,g
        "w0": Param((d,), (None,)),  # static decay bias
        "w_lora_a": Param((d, lora), ("embed", None)),
        "w_lora_b": Param((lora, d), (None, "embed")),
        "u": Param((h, c), ("heads", None)),  # per-head bonus
        "wr": Param((d, d), ("embed", "heads")),
        "wk": Param((d, d), ("embed", "heads")),
        "wv": Param((d, d), ("embed", "heads")),
        "wg": Param((d, d), ("embed", "heads")),
        "wo": Param((d, d), ("heads", "embed")),
        "ln_x": Param((d,), (None,), init="ones"),  # group-norm scale
    }


def _cm_specs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu": Param((2, d), (None, None)),
        "wk": Param((d, f), ("embed", "mlp")),
        "wv": Param((f, d), ("mlp", "embed")),
        "wr": Param((d, d), ("embed", "embed")),
    }


def _block_specs(cfg):
    return {
        "ln1": L.norm_specs(cfg),
        "tm": _tm_specs(cfg),
        "ln2": L.norm_specs(cfg),
        "cm": _cm_specs(cfg),
    }


def specs(cfg):
    return {
        "embed": L.embed_specs(cfg),
        "blocks": stack_specs(_block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg),
    }


def _shift(x, x_prev):
    """Token shift: returns x_{t-1} sequence given chunk + carry-in."""
    return jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _time_mix(p, x, cfg, x_prev, state):
    """x: (B,S,d); x_prev: (B,d) carry-in; state: (B,H,c,c).

    Returns (out, new_x_prev, new_state)."""
    b, s, d = x.shape
    h, c = _heads(cfg), cfg.rwkv_head_dim
    dt = x.dtype
    xs = _shift(x, x_prev)
    mix = lambda i: x + (xs - x) * p["mu"][i].astype(dt)
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))

    r = (xr @ p["wr"].astype(dt)).reshape(b, s, h, c)
    k = (xk @ p["wk"].astype(dt)).reshape(b, s, h, c)
    v = (xv @ p["wv"].astype(dt)).reshape(b, s, h, c)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    # data-dependent decay (the Finch contribution): w = exp(-exp(..))
    dw = (xw @ p["w_lora_a"].astype(dt)) @ p["w_lora_b"].astype(dt)
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dw.astype(jnp.float32))))
    w = w.reshape(b, s, h, c)
    u = p["u"].astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,c) each
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,c,c)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    rs, ks, vs, ws = (t.swapaxes(0, 1).astype(jnp.float32)
                      for t in (r, k, v, w))  # (S,B,H,c)
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rs, ks, vs, ws))
    y = ys.swapaxes(0, 1).reshape(b, s, d)  # (B,S,d)
    # per-head group norm
    yh = y.reshape(b, s, h, c)
    yh = yh * jax.lax.rsqrt(jnp.mean(yh**2, axis=-1, keepdims=True) + 1e-6)
    y = (yh.reshape(b, s, d) * p["ln_x"].astype(jnp.float32)).astype(dt)
    out = (y * g) @ p["wo"].astype(dt)
    return out, x[:, -1], state


def _channel_mix(p, x, cfg, x_prev):
    dt = x.dtype
    xs = _shift(x, x_prev)
    xk = x + (xs - x) * p["mu"][0].astype(dt)
    xr = x + (xs - x) * p["mu"][1].astype(dt)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(dt)))
    k = constrain(k, ("batch", "seq", "mlp"))
    r = jax.nn.sigmoid(xr @ p["wr"].astype(dt))
    return r * (k @ p["wv"].astype(dt)), x[:, -1]


def _apply_block(p, x, cfg, cache):
    h, tm_prev, st = _time_mix(p["tm"], L.apply_norm(p["ln1"], x, cfg), cfg,
                               cache["tm_prev"], cache["wkv"])
    x = x + h
    h, cm_prev = _channel_mix(p["cm"], L.apply_norm(p["ln2"], x, cfg), cfg,
                              cache["cm_prev"])
    x = constrain(x + h, ("batch", "seq", "embed"))
    return x, {"tm_prev": tm_prev, "cm_prev": cm_prev,
               "wkv": st.astype(cache["wkv"].dtype)}


def cache_specs(cfg, batch: int, cache_len: int):
    """RWKV state is O(1) in sequence length (cache_len unused)."""
    del cache_len
    h, c, d = _heads(cfg), cfg.rwkv_head_dim, cfg.d_model
    lyr = cfg.n_layers
    return {
        "tm_prev": (jax.ShapeDtypeStruct((lyr, batch, d), jnp.float32),
                    ("layers", "batch", "embed")),
        "cm_prev": (jax.ShapeDtypeStruct((lyr, batch, d), jnp.float32),
                    ("layers", "batch", "embed")),
        "wkv": (jax.ShapeDtypeStruct((lyr, batch, h, c, c), jnp.float32),
                ("layers", "batch", "heads", None, None)),
    }


def _zero_cache(cfg, b):
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        cache_specs(cfg, b, 0),
                        is_leaf=lambda x: isinstance(x, tuple))


def _forward(params, tokens, cfg, caches):
    dt = dtype_of(cfg)
    x = L.embed_lookup(params["embed"], tokens, cfg, dt)

    def body(h, xs):
        layer_p, layer_c = xs
        h, new_c = _apply_block(layer_p, h, cfg, layer_c)
        return h, new_c

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
    return L.apply_norm(params["ln_f"], x, cfg), new_caches


def loss(params, batch, cfg):
    b = batch["tokens"].shape[0]
    x, _ = _forward(params, batch["tokens"], cfg, _zero_cache(cfg, b))
    return L.chunked_cross_entropy(x, params["embed"], batch["labels"], cfg,
                                   loss_mask=batch.get("loss_mask"))


def prefill(params, batch, caches, cfg):
    x, caches = _forward(params, batch["tokens"], cfg, caches)
    from repro.models.transformer import _last_logits

    return _last_logits(params, x, cfg), caches


def decode(params, tokens, pos, kv_len, caches, cfg):
    del pos, kv_len  # positions are implicit in the recurrent state
    b = tokens.shape[0]
    x, caches = _forward(params, tokens.reshape(b, 1), cfg, caches)
    from repro.models.transformer import _last_logits

    return _last_logits(params, x, cfg), caches


def make_model_def():
    return ModelDef(specs=specs, loss=loss, prefill=prefill, decode=decode,
                    cache_specs=cache_specs)
