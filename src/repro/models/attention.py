"""Model-level attention block: projections, RoPE, KV cache, CAMformer modes.

The KV cache comes in two layouts (first-class CAMformer integration):

  * dense:     k, v in model dtype (B, H_kv, S, D)            — baseline.
  * camformer: k stored BIT-PACKED (B, H_kv, S, D/32) uint32  — the paper's
               Key SRAM holds binarized keys; 6.25% of the BF16 footprint
               (Sec. III-C1).  v stays bf16 (1/1/16 of Table II).  A running
               per-head key scale rides along for the softmax temperature.

Decode against the packed cache performs the paper's "CAM search over a
growing KV cache": Hamming scores via popcount (Pallas kernel for long
caches), two-stage top-k, softmax over 32 survivors, sparse V gather.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bacam
from repro.core.attention import (AttentionSpec, attention,
                                  camformer_paged_attention,
                                  topk_softmax_weights)
from repro.core.binarize import sign_pm1
from repro.core.topk import NEG_INF, two_stage_topk
from repro.models.layers import rope
from repro.models.module import Param
from repro.sharding.partitioning import constrain
from repro.utils import compat

__all__ = [
    "attn_specs", "attn_cache_spec", "attn_page_spec", "attention_block",
    "spec_from_cfg",
]


def spec_from_cfg(cfg) -> AttentionSpec:
    return AttentionSpec(
        mode=cfg.attn_mode,
        k_top=cfg.k_top,
        group_size=cfg.group_size,
        stage1_k=cfg.stage1_k,
        use_kernel=cfg.use_kernel,
    )


def attn_specs(cfg, cross: bool = False):
    d = cfg.d_model
    s = {
        "wq": Param((d, cfg.q_dim), ("embed", "heads")),
        "wk": Param((d, cfg.kv_dim), ("embed", "kv_heads")),
        "wv": Param((d, cfg.kv_dim), ("embed", "kv_heads")),
        "wo": Param((cfg.q_dim, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Param((cfg.q_dim,), (None,), init="zeros")
        s["bk"] = Param((cfg.kv_dim,), (None,), init="zeros")
        s["bv"] = Param((cfg.kv_dim,), (None,), init="zeros")
    return s


def attn_cache_spec(cfg, batch: int, cache_len: int, dtype):
    """ShapeDtypeStructs + logical axes for one layer's self-attn cache."""
    hkv, d = cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_mode == "camformer":
        return {
            "k_packed": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d // 32), jnp.uint32),
                         ("batch", "kv_heads", "kv_seq", None)),
            "v": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
                  ("batch", "kv_heads", "kv_seq", "head_dim")),
            "k_scale": (jax.ShapeDtypeStruct((batch, hkv), jnp.float32),
                        ("batch", "kv_heads")),
        }
    return {
        "k": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
              ("batch", "kv_heads", "kv_seq", "head_dim")),
        "v": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
              ("batch", "kv_heads", "kv_seq", "head_dim")),
    }


def attn_page_spec(cfg, n_pages: int, page_size: int, max_batch: int, dtype):
    """ShapeDtypeStructs + logical axes for one layer's PAGED self-attn
    cache (serving/kv_cache.py layout): bit-packed keys and dense values in
    fixed-size physical pages, plus the per-slot running key scale."""
    hkv, d = cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_mode != "camformer":
        raise ValueError("paged KV cache requires attn_mode='camformer'")
    if page_size % cfg.group_size != 0:
        raise ValueError(
            f"page_size={page_size} must tile by group_size={cfg.group_size}")
    return {
        "kp_pages": (jax.ShapeDtypeStruct(
            (n_pages, hkv, page_size, d // 32), jnp.uint32),
            (None, "kv_heads", None, None)),
        "v_pages": (jax.ShapeDtypeStruct(
            (n_pages, hkv, page_size, d), dtype),
            (None, "kv_heads", None, "head_dim")),
        "k_scale": (jax.ShapeDtypeStruct((max_batch, hkv), jnp.float32),
                    ("batch", "kv_heads")),
    }


def _paged_write(cache, k, v, positions, page_table, kv_len, cfg):
    """Splice new K/V into the paged pools at their logical positions.

    k, v: (B, H_kv, S, D); positions: (B, S) logical token positions;
    kv_len: (B,) — valid tokens per slot INCLUDING this write (prefill:
    the true prompt length; decode: pos + 1).  Tokens at positions >=
    kv_len are right-padding: their page-table entries resolve to the
    trash page and they are excluded from the k_scale running mean.
    """
    page = cache["kp_pages"].shape[2]
    b, hkv, s, _ = k.shape
    pos = positions.astype(jnp.int32)
    kv_len = kv_len.reshape(b).astype(jnp.int32)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    phys = page_table[bidx, pos // page]  # (B, S) physical pages
    row = pos % page

    kp = bacam.pack_bits(sign_pm1(k))  # (B, H_kv, S, W)
    new_kp = cache["kp_pages"].at[phys, :, row].set(kp.transpose(0, 2, 1, 3))
    new_v = cache["v_pages"].at[phys, :, row].set(
        v.astype(cache["v_pages"].dtype).transpose(0, 2, 1, 3))

    # Running per-slot/head key scale over VALID tokens only.
    valid = (pos < kv_len[:, None]).astype(jnp.float32)  # (B, S)
    mean_d = jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=3)  # (B,Hkv,S)
    new_sum = jnp.einsum("bhs,bs->bh", mean_d, valid)
    cnt = jnp.sum(valid, axis=-1)  # (B,)
    prior = jnp.minimum(pos[:, 0], kv_len).astype(jnp.float32)
    total = prior + cnt
    ks = ((cache["k_scale"] * prior[:, None] + new_sum)
          / jnp.maximum(total, 1.0)[:, None])
    ks = jnp.where((total > 0)[:, None], ks, cache["k_scale"])
    return {"kp_pages": new_kp, "v_pages": new_v, "k_scale": ks}


def _paged_cam_attend(q, cache, page_table, kv_len, positions, cfg, spec):
    """Decode/prefill attention against the paged bit-packed cache."""
    return camformer_paged_attention(
        q, cache["kp_pages"], cache["v_pages"], cache["k_scale"],
        page_table, kv_len, positions, spec, window=cfg.window)


def _project(p, x, cfg, training: bool = True):
    dt = x.dtype
    b, s, _ = x.shape
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = constrain(q, ("batch", "seq", "heads"))
    k = constrain(k, ("batch", "seq", "kv_heads"))
    v = constrain(v, ("batch", "seq", "kv_heads"))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if s > 1:
        strat = _attn_strategy(cfg, training)
        if strat == "heads":  # KV heads divide `model`: fully head-parallel
            q = constrain(q, ("batch", "heads", None, "head_dim"))
            k = constrain(k, ("batch", "kv_heads", None, "head_dim"))
            v = constrain(v, ("batch", "kv_heads", None, "head_dim"))
        elif strat == "qheads":  # GQA: Q heads shard, KV replicates (Megatron)
            q = constrain(q, ("batch", "heads", None, "head_dim"))
            k = constrain(k, ("batch", None, None, "head_dim"))
            v = constrain(v, ("batch", None, None, "head_dim"))
        elif strat == "q_seq":  # inference, heads indivisible: shard Sq —
            # softmax/AV stay local (no backward pass to reshard)
            q = constrain(q, ("batch", None, "att_q_seq", "head_dim"))
            k = constrain(k, ("batch", None, None, "head_dim"))
            v = constrain(v, ("batch", None, None, "head_dim"))
        elif strat == "kv_seq":  # training, heads indivisible: context par.
            q = constrain(q, ("batch", None, None, "head_dim"))
            k = constrain(k, ("batch", None, "att_kv_seq", "head_dim"))
            v = constrain(v, ("batch", None, "att_kv_seq", "head_dim"))
    return q, k, v


def _attn_strategy(cfg, training: bool = True) -> str:
    """Pick the attention sharding strategy from head-count divisibility
    against the ambient mesh's `model` axis (DESIGN.md §5):
      heads:  n_kv_heads % model == 0  — everything head-local.
      qheads: n_heads % model == 0    — Q heads shard, KV replicated.
      else (yi-34b 56H, granite 24H, recurrentgemma 10H):
        q_seq  (inference) — shard the QUERY sequence; softmax and AV are
               fully local since there is no backward pass to reshard.
        kv_seq (training)  — shard the KEY sequence (context parallel);
               GSPMD's backward for q_seq triggers involuntary full-score
               rematerialization, kv_seq keeps bwd local modulo small
               softmax-stat reduces + the AV partial-sum all-reduce.
    """
    env = compat.get_abstract_mesh()
    if env is None or "model" not in getattr(env, "shape", {}):
        return "none"
    m = env.shape["model"]
    if cfg.n_kv_heads % m == 0:
        return "heads"
    if cfg.n_heads % m == 0:
        return "qheads"
    return "kv_seq" if training else "q_seq"


def _seq_insert(buf, upd, index):
    """Insert `upd` into `buf` along axis 2 (cache seq).

    index: scalar — uniform write (train/prefill/dry-run decode);
           (B,) array — ragged per-slot write (continuous batching).
    """
    zero = jnp.zeros((), jnp.int32)
    if jnp.ndim(index) == 0:
        return jax.lax.dynamic_update_slice(buf, upd, (zero, zero, index, zero))
    one = lambda b, u, i: jax.lax.dynamic_update_slice(b, u, (zero, i, zero))
    return jax.vmap(one)(buf, upd, index.astype(jnp.int32))


def _write_cache(cache, k, v, index, cfg):
    """Insert new K/V at `index` (traced) along the cache sequence axis.

    If the update is longer than the cache (window ring-buffer prefill),
    only the trailing cache-length slice is stored at index 0.
    """
    if cache is None:
        return None
    cache_len = cache["v"].shape[2]
    if k.shape[2] > cache_len:
        k, v = k[:, :, -cache_len:], v[:, :, -cache_len:]
        index = jnp.int32(0)
    if "k_packed" in cache:
        kp = bacam.pack_bits(sign_pm1(k))
        new_kp = _seq_insert(cache["k_packed"], kp, index)
        new_v = _seq_insert(cache["v"], v.astype(cache["v"].dtype), index)
        # running per-head key scale (softmax temperature bookkeeping)
        step = jnp.float32(k.shape[2])
        new_mean = jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=(2, 3))
        idx_f = jnp.reshape(index.astype(jnp.float32), (-1, 1))
        total = idx_f + step
        k_scale = (cache["k_scale"] * idx_f + new_mean * step) / total
        return {"k_packed": new_kp, "v": new_v, "k_scale": k_scale}
    new_k = _seq_insert(cache["k"], k.astype(cache["k"].dtype), index)
    new_v = _seq_insert(cache["v"], v.astype(cache["v"].dtype), index)
    return {"k": new_k, "v": new_v}


def _distributed_cam_attend(q, cache, kv_len, positions, cfg, spec):
    """Distributed CAM search (paper Sec. IV-C at cluster scale).

    The packed-binary cache is sequence-sharded across the mesh; each shard
    runs the BA-CAM scoring + two-stage top-k LOCALLY, shards exchange only
    their k candidates (k*(8 B) per query per shard — vs gathering the full
    N-score matchline vector), the global top-k/softmax is computed
    redundantly everywhere, and contextualization is a masked partial sum
    over local V rows finished by one psum.
    """
    env = compat.get_abstract_mesh()
    axes = tuple(a for a in ("pod", "data", "model")
                 if a in getattr(env, "shape", {}) and env.shape[a] > 1)
    if not axes:
        return _camformer_cache_attend(q, cache, kv_len, positions, cfg, spec)
    import math

    from jax.sharding import PartitionSpec as P

    b, h, sq, d = q.shape
    hkv = cfg.n_kv_heads
    g = h // hkv
    skv = cache["v"].shape[2]
    n_shards = math.prod(env.shape[a] for a in axes)
    s_local = skv // n_shards
    qb = sign_pm1(q.astype(jnp.float32))
    q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)  # (B,H,Sq)
    qp = bacam.pack_bits(qb).reshape(b, hkv, g * sq, d // 32)

    k_top = spec.k_top

    def local_fn(qp_l, kp_l, v_l, kscale_l, qscale_l, pos_l, kvlen_l):
        # shard offset along the cache sequence
        idx = 0
        for a in axes:
            idx = idx * env.shape[a] + jax.lax.axis_index(a)
        offset = idx * s_local
        scores = bacam.hamming_scores_packed(qp_l, kp_l, d).astype(jnp.float32)
        kpos = offset + jnp.arange(s_local, dtype=jnp.int32)[None, None, None]
        qpos = jnp.broadcast_to(pos_l[:, None, :], (b, hkv, sq))
        qpos = jnp.broadcast_to(qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
            b, hkv, g * sq)[..., None]
        ok = (kpos < kvlen_l.reshape(b, 1, 1, 1)) & (kpos <= qpos)
        if cfg.window is not None:
            ok = ok & (kpos > qpos - cfg.window)
        masked = jnp.where(ok, scores, NEG_INF)
        lv, li = two_stage_topk(masked, k=k_top, group_size=spec.group_size,
                                stage1_k=spec.stage1_k)  # local top-k
        li = li + offset  # globalize indices
        # exchange candidates only: (B,Hkv,R,k) per shard
        cv = jax.lax.all_gather(lv, axes, axis=-1, tiled=True)
        ci = jax.lax.all_gather(li, axes, axis=-1, tiled=True)
        top_v, sel = jax.lax.top_k(cv, k_top)  # identical on every shard
        top_i = jnp.take_along_axis(ci, sel, axis=-1)
        scale = 1.0 / (d**0.5)
        temp = (qscale_l.reshape(b, hkv, g * sq)[..., None]
                * kscale_l[:, :, None, None])
        w, valid = topk_softmax_weights(top_v, temp, scale)  # (B,Hkv,R,k)
        # partial contextualization over local V rows
        mine = (top_i >= offset) & (top_i < offset + s_local) & valid
        loc = jnp.clip(top_i - offset, 0, s_local - 1)
        v_exp = v_l[:, :, None]  # (B,Hkv,1,S_local,D)
        v_sel = jnp.take_along_axis(v_exp, loc[..., None], axis=-2)
        contrib = jnp.einsum("bhrk,bhrkd->bhrd",
                             jnp.where(mine, w, 0.0).astype(jnp.float32),
                             v_sel.astype(jnp.float32))
        return jax.lax.psum(contrib, axes)

    seq_spec = P(None, None, axes, None)
    out = compat.shard_map(
        local_fn,
        mesh=env,
        in_specs=(P(), seq_spec,
                  P(None, None, axes, None), P(), P(), P(), P()),
        out_specs=P(),
    )(qp, cache["k_packed"], cache["v"], cache["k_scale"], q_scale,
      positions, kv_len)
    out = out.reshape(b, hkv, g, sq, d).reshape(b, h, sq, d)
    return out.astype(q.dtype)


def _camformer_cache_attend(q, cache, kv_len, positions, cfg, spec,
                            kv_positions=None):
    """Decode/serve attention against the packed binary cache."""
    b, h, sq, d = q.shape
    hkv = cfg.n_kv_heads
    g = h // hkv
    skv = cache["v"].shape[2]
    qb = sign_pm1(q.astype(jnp.float32))
    q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)  # (B,H,Sq)

    qp = bacam.pack_bits(qb).reshape(b * hkv, g * sq, d // 32)
    kp = cache["k_packed"].reshape(b * hkv, skv, d // 32)
    if spec.use_kernel and kv_positions is not None:
        # the fused kernel masks from slot order; ring caches with rotated
        # positions take the jnp path instead
        spec = spec.replace(use_kernel=False)
    if spec.use_kernel:
        from repro.kernels import ops as kops

        pos = jnp.broadcast_to(
            positions[:, None, :], (b, hkv, g * sq)).reshape(b * hkv, g * sq)
        kvl = jnp.broadcast_to(kv_len.reshape(b, 1), (b, hkv)).reshape(b * hkv)
        cand_v, cand_i = kops.bacam_attention_scores_topk_packed(
            qp, kp, pos, kvl, d=d,
            group=spec.group_size, stage1_k=spec.stage1_k,
            causal=True, window=cfg.window)
        top_v, sel = jax.lax.top_k(cand_v, min(spec.k_top, cand_v.shape[-1]))
        top_i = jnp.take_along_axis(cand_i, sel, axis=-1)
        top_v = top_v.reshape(b, hkv, g, sq, -1)
        top_i = top_i.reshape(b, hkv, g, sq, -1)
    else:
        scores = bacam.hamming_scores_packed(
            qp.reshape(b, hkv, g * sq, d // 32),
            kp.reshape(b, hkv, skv, d // 32),
            d,
        )  # (B,Hkv,G*Sq,Skv)
        if kv_positions is None:
            kpos = jnp.arange(skv, dtype=jnp.int32)[None, None, None]
        else:  # ring cache: slots hold true (rotated) positions
            kpos = kv_positions[:, None, None, :]
        qpos = jnp.broadcast_to(positions[:, None, :], (b, hkv, sq))
        qpos = jnp.broadcast_to(qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
            b, hkv, g * sq)[..., None]
        ok = kpos < kv_len.reshape(b, 1, 1, 1)
        ok = ok & (kpos <= qpos)
        if cfg.window is not None:
            ok = ok & (kpos > qpos - cfg.window)
        masked = jnp.where(ok, scores.astype(jnp.float32), NEG_INF)
        top_v, top_i = two_stage_topk(
            masked, k=spec.k_top, group_size=spec.group_size,
            stage1_k=spec.stage1_k)
        top_v = top_v.reshape(b, hkv, g, sq, -1)
        top_i = top_i.reshape(b, hkv, g, sq, -1)

    scale = 1.0 / (d**0.5)
    temp = q_scale.reshape(b, hkv, g, sq)[..., None] * cache["k_scale"][:, :, None, None, None]
    w, _ = topk_softmax_weights(top_v, temp, scale)
    v_exp = cache["v"][:, :, None, None]  # (B,Hkv,1,1,Skv,Dv)
    v_sel = jnp.take_along_axis(v_exp, top_i[..., None], axis=-2)
    out = jnp.einsum("bhgqk,bhgqkd->bhgqd", w.astype(cache["v"].dtype), v_sel)
    return out.reshape(b, h, sq, d).astype(q.dtype)


def attention_block(
    p,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    cache=None,
    cache_index=None,
    kv_len=None,
    kv_positions=None,
    page_table=None,
    causal: bool = True,
    window: int | None = None,
    cross_kv=None,
):
    """Full attention sub-block. Returns (out (B,S,d_model), new_cache).

    Modes of operation:
      train:          cache=None                       — full self-attention
      prefill:        cache empty, cache_index=0       — attn + cache write
      decode:         cache filled, cache_index=pos    — 1-token query
      paged serving:  cache is a page-pool dict, page_table set — prefill
                      chunks and decode both splice into pages and attend
                      through the page table (no contiguous KV buffer)
      cross-attention: cross_kv=(k, v) precomputed     — no cache write
    """
    b, s, _ = x.shape
    dt = x.dtype
    q, k, v = _project(p, x, cfg, training=cache is None and cross_kv is None)
    spec = spec_from_cfg(cfg)

    if cross_kv is not None:
        k, v = cross_kv
        # Paper Sec. IV-C: enc-dec models use non-causal CAM search over
        # encoder keys — camformer mode applies to cross-attention too.
        out = attention(q, k, v, spec, causal=False)
    else:
        if getattr(cfg, "use_rope", True):
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if cache is not None and "kp_pages" in cache:
            if page_table is None or kv_len is None:
                raise ValueError("paged cache needs page_table and kv_len")
            new_cache = _paged_write(
                cache, k, v, positions, page_table, kv_len, cfg)
            out = _paged_cam_attend(
                q, new_cache, page_table, kv_len, positions, cfg, spec)
            out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
            out = constrain(out, ("batch", "seq", "heads"))
            return (out @ p["wo"].astype(dt)), new_cache
        new_cache = _write_cache(
            cache, k, v,
            cache_index if cache_index is not None else jnp.int32(0), cfg)
        if cache is not None and kv_len is not None:
            # decode / cached path: attend over the (partially valid) cache
            if "k_packed" in new_cache:
                # distributed CAM search targets the batch=1 long-context
                # regime where the cache sequence takes every mesh axis;
                # batched decode keeps batch-sharded local search instead
                if cfg.distributed_topk and kv_positions is None and b == 1:
                    out = _distributed_cam_attend(
                        q, new_cache, kv_len, positions, cfg, spec)
                else:
                    out = _camformer_cache_attend(
                        q, new_cache, kv_len, positions, cfg, spec,
                        kv_positions=kv_positions)
            else:
                ck, cv = new_cache["k"], new_cache["v"]
                kv_pos = (jnp.arange(ck.shape[2], dtype=jnp.int32)[None]
                          if kv_positions is None else kv_positions)
                kv_valid = kv_pos < kv_len.reshape(-1, 1)
                out = attention(
                    q, ck, cv, spec, causal=True,
                    q_positions=positions, kv_positions=kv_pos,
                    kv_valid=kv_valid, window=window or cfg.window)
        else:
            # train / prefill: attend over freshly-computed K/V
            out = attention(
                q, k, v, spec, causal=causal,
                q_positions=positions, window=window or cfg.window)
        cache = new_cache

    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    # Preserve the attention-interior layout on the way out: under q_seq the
    # output stays sequence-sharded (forcing head-sharding here would make
    # GSPMD gather the full score tensor to replicate the sequence axis).
    if s > 1 and _attn_strategy(cfg, cache is None and cross_kv is None) == "q_seq":
        out = constrain(out, ("batch", "att_q_seq", "heads"))
    else:
        out = constrain(out, ("batch", "seq", "heads"))
    return (out @ p["wo"].astype(dt)), cache
