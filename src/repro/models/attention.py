"""Model-level attention block: projections, RoPE, sharding strategy, and
dispatch to a pluggable ``AttentionBackend`` (core/backend.py).

The block owns everything physical-realization-*independent* — QKV
projections, RoPE, GQA head layout, the mesh-aware sharding strategy —
and hands the realization itself (cache layout, scoring arithmetic, paged
pools, fused kernels) to the layer's backend:

  * dense:     bf16 K/V caches & pages, softmax attention — baseline.
  * binary:    dense storage, HAD-binarized scoring, full softmax.
  * camformer: keys stored BIT-PACKED (B, H_kv, S, D/32) uint32 — the
               paper's Key SRAM holds binarized keys; 6.25% of the BF16
               footprint (Sec. III-C1).  v stays bf16 (1/1/16 of
               Table II).  A running per-head key scale rides along for
               the softmax temperature.

Per-layer policy: callers pass ``backend=`` (resolved by the model from
``cfg.backend_for(layer)``); without it the block uses the config's
uniform backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.backend import AttentionBackend, get_backend
from repro.models.layers import rope
from repro.models.module import Param
from repro.sharding.partitioning import constrain
from repro.utils import compat

__all__ = [
    "attn_specs", "attn_cache_spec", "attention_block",
]


def _resolve_backend(cfg, backend=None) -> AttentionBackend:
    if isinstance(backend, AttentionBackend):
        return backend
    return get_backend(backend or cfg.backend)


def attn_specs(cfg, cross: bool = False):
    d = cfg.d_model
    s = {
        "wq": Param((d, cfg.q_dim), ("embed", "heads")),
        "wk": Param((d, cfg.kv_dim), ("embed", "kv_heads")),
        "wv": Param((d, cfg.kv_dim), ("embed", "kv_heads")),
        "wo": Param((cfg.q_dim, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = Param((cfg.q_dim,), (None,), init="zeros")
        s["bk"] = Param((cfg.kv_dim,), (None,), init="zeros")
        s["bv"] = Param((cfg.kv_dim,), (None,), init="zeros")
    return s


def attn_cache_spec(cfg, batch: int, cache_len: int, dtype, backend=None):
    """ShapeDtypeStructs + logical axes for one layer's self-attn cache
    (delegates to the layer's backend)."""
    return _resolve_backend(cfg, backend).cache_spec(
        cfg, batch, cache_len, dtype)


def _project(p, x, cfg, training: bool = True):
    dt = x.dtype
    b, s, _ = x.shape
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = constrain(q, ("batch", "seq", "heads"))
    k = constrain(k, ("batch", "seq", "kv_heads"))
    v = constrain(v, ("batch", "seq", "kv_heads"))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if s > 1:
        strat = _attn_strategy(cfg, training)
        if strat == "heads":  # KV heads divide `model`: fully head-parallel
            q = constrain(q, ("batch", "heads", None, "head_dim"))
            k = constrain(k, ("batch", "kv_heads", None, "head_dim"))
            v = constrain(v, ("batch", "kv_heads", None, "head_dim"))
        elif strat == "qheads":  # GQA: Q heads shard, KV replicates (Megatron)
            q = constrain(q, ("batch", "heads", None, "head_dim"))
            k = constrain(k, ("batch", None, None, "head_dim"))
            v = constrain(v, ("batch", None, None, "head_dim"))
        elif strat == "q_seq":  # inference, heads indivisible: shard Sq —
            # softmax/AV stay local (no backward pass to reshard)
            q = constrain(q, ("batch", None, "att_q_seq", "head_dim"))
            k = constrain(k, ("batch", None, None, "head_dim"))
            v = constrain(v, ("batch", None, None, "head_dim"))
        elif strat == "kv_seq":  # training, heads indivisible: context par.
            q = constrain(q, ("batch", None, None, "head_dim"))
            k = constrain(k, ("batch", None, "att_kv_seq", "head_dim"))
            v = constrain(v, ("batch", None, "att_kv_seq", "head_dim"))
    return q, k, v


def _attn_strategy(cfg, training: bool = True) -> str:
    """Pick the attention sharding strategy from head-count divisibility
    against the ambient mesh's `model` axis (DESIGN.md §5):
      heads:  n_kv_heads % model == 0  — everything head-local.
      qheads: n_heads % model == 0    — Q heads shard, KV replicated.
      else (yi-34b 56H, granite 24H, recurrentgemma 10H):
        q_seq  (inference) — shard the QUERY sequence; softmax and AV are
               fully local since there is no backward pass to reshard.
        kv_seq (training)  — shard the KEY sequence (context parallel);
               GSPMD's backward for q_seq triggers involuntary full-score
               rematerialization, kv_seq keeps bwd local modulo small
               softmax-stat reduces + the AV partial-sum all-reduce.
    """
    env = compat.get_abstract_mesh()
    if env is None or "model" not in getattr(env, "shape", {}):
        return "none"
    m = env.shape["model"]
    if cfg.n_kv_heads % m == 0:
        return "heads"
    if cfg.n_heads % m == 0:
        return "qheads"
    return "kv_seq" if training else "q_seq"


def _tp_paged_decode(bk, q, cache, k, v, positions, page_table, kv_len,
                     cfg, scale_base):
    """Paged attention over a head-sharded pool slice (the tensor-parallel
    serving seam — see serving/sharded.py for the subsystem design).

    Runs inside the sharded engine's ``shard_map`` body: every pool leaf
    carries only this device's kv-head slice (detected by comparing the
    pool's head extent against ``cfg.n_kv_heads``), while q/k/v from the
    replicated projections carry all heads.  Slice q/k/v to the local
    contiguous head range (q heads are grouped per kv head, so kv heads
    ``[i*hl, (i+1)*hl)`` own q heads ``[i*g*hl, (i+1)*g*hl)``), run the
    backend's paged write+attend unchanged on the slice (all paged
    attention code derives head counts from array shapes), and
    reassemble the per-head outputs with an ``all_gather`` over ``tp``.
    The gather is pure concatenation — no arithmetic — so the block
    output, and every downstream logit and keyed sample, stays
    bit-identical to the single-device engine at any tp degree; a psum
    of partial ``wo`` projections would reorder floating-point sums and
    break token-for-token identity.
    """
    hl = cache["v_pages"].shape[1]  # kv heads local to this device
    g = cfg.n_heads // cfg.n_kv_heads
    i = jax.lax.axis_index("tp")
    q = jax.lax.dynamic_slice_in_dim(q, i * g * hl, g * hl, axis=1)
    k = jax.lax.dynamic_slice_in_dim(k, i * hl, hl, axis=1)
    v = jax.lax.dynamic_slice_in_dim(v, i * hl, hl, axis=1)
    out, new_cache = bk.paged_decode(
        q, cache, k, v, positions, page_table, kv_len, cfg,
        base=scale_base)
    out = jax.lax.all_gather(out, "tp", axis=1, tiled=True)
    return out, new_cache


def attention_block(
    p,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    cache=None,
    cache_index=None,
    kv_len=None,
    kv_positions=None,
    page_table=None,
    scale_base=None,
    causal: bool = True,
    window: int | None = None,
    cross_kv=None,
    backend=None,
):
    """Full attention sub-block. Returns (out (B,S,d_model), new_cache).

    Modes of operation:
      train:          cache=None                       — full self-attention
      prefill:        cache empty, cache_index=0       — attn + cache write
      decode:         cache filled, cache_index=pos    — 1-token query
      paged serving:  cache is a page-pool dict, page_table set — prefill
                      chunks and decode both splice into pages and attend
                      through the page table (no contiguous KV buffer)
      cross-attention: cross_kv=(k, v) precomputed     — no cache write

    ``backend`` selects the physical realization (an AttentionBackend or
    registry name); default is the config's uniform backend.
    """
    bk = _resolve_backend(cfg, backend)
    b, s, _ = x.shape
    dt = x.dtype
    q, k, v = _project(p, x, cfg, training=cache is None and cross_kv is None)

    if cross_kv is not None:
        k, v = cross_kv
        # Paper Sec. IV-C: enc-dec models use non-causal CAM search over
        # encoder keys — the backend applies to cross-attention too.
        out = bk.prefill(q, k, v, cfg, causal=False)
    else:
        if getattr(cfg, "use_rope", True):
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        if cache is not None and "v" not in cache and page_table is None:
            # a paged pool (k_pages/kp_pages + v_pages) reached the
            # contiguous path — fail loudly, not with a KeyError below
            raise ValueError("paged cache needs page_table and kv_len")
        if page_table is not None and cache is not None:
            if kv_len is None:
                raise ValueError("paged cache needs page_table and kv_len")
            if cache["v_pages"].shape[1] != cfg.n_kv_heads:
                # head-sharded pool slice: inside the tensor-parallel
                # engine's shard_map body (serving/sharded.py)
                out, new_cache = _tp_paged_decode(
                    bk, q, cache, k, v, positions, page_table, kv_len,
                    cfg, scale_base)
            else:
                out, new_cache = bk.paged_decode(
                    q, cache, k, v, positions, page_table, kv_len, cfg,
                    base=scale_base)
            out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
            out = constrain(out, ("batch", "seq", "heads"))
            return (out @ p["wo"].astype(dt)), new_cache
        index = cache_index if cache_index is not None else jnp.int32(0)
        if cache is not None and kv_len is not None:
            # decode / cached path: attend over the (partially valid) cache
            out, cache = bk.decode(
                q, cache, k, v, index, kv_len, positions, cfg,
                kv_positions=kv_positions, window=window)
        else:
            # train / prefill: attend over freshly-computed K/V
            cache = bk.write_cache(cache, k, v, index, cfg)
            out = bk.prefill(
                q, k, v, cfg, causal=causal,
                positions=positions, window=window or cfg.window)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    # Preserve the attention-interior layout on the way out: under q_seq the
    # output stays sequence-sharded (forcing head-sharding here would make
    # GSPMD gather the full score tensor to replicate the sequence axis).
    if s > 1 and _attn_strategy(cfg, cache is None and cross_kv is None) == "q_seq":
        out = constrain(out, ("batch", "att_q_seq", "heads"))
    else:
        out = constrain(out, ("batch", "seq", "heads"))
    return (out @ p["wo"].astype(dt)), cache
