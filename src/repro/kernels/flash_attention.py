"""Pallas TPU kernel: dense flash attention (forward), the paper's baseline.

Online-softmax attention with O(S) memory, used (a) as the full-precision
dense baseline CAMformer is compared against, and (b) for serving prefill.
Layout is per-head 3D (B*, S, D); the ops wrapper folds (batch, heads).

Grid (B, Sq/bq, Skv/bk) with the KV dimension innermost and sequential
("arbitrary" on TPU); running max/denominator/accumulator live in VMEM
scratch that persists across the KV sweep (canonical TPU flash pattern).

VMEM (bq=bk=512, D<=256): q/k/v blocks 3*512*256*4 B = 1.5 MiB + acc
512*256*4 = 0.5 MiB + s/p 512*512*4 = 1 MiB  =>  ~3 MiB of 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.topk import NEG_INF


def _kernel(
    off_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, window: int | None, block_q: int, block_k: int,
):
    i, j = pl.program_id(1), pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    qpos = off_ref[0, 0] + i * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones_like(kpos, dtype=jnp.bool_)
    if causal:
        ok = jnp.logical_and(ok, kpos <= qpos)
    if window is not None:
        ok = jnp.logical_and(ok, kpos > qpos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(ok, p, 0.0)  # fully-masked rows stay all-zero
    l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[:, 0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_offset: jax.Array | int = 0,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Flash attention forward. q: (B, Sq, D); k, v: (B, Skv, D)."""
    b, sq, d = q.shape
    skv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    off = jnp.full((1, 1), q_offset, jnp.int32)
    grid = (b, sq // block_q, skv // block_k)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, i, j: (0, 0)),
            pl.BlockSpec((1, block_q, d), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b_, i, j: (b_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(off, q, k, v)
