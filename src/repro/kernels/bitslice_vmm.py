"""Bit-sliced binary-integer VMM on the BA-CAM engine (paper Sec. II-B1).

"For higher-precision [operands], we decompose entries into binary slices
(LSB -> MSB) and run per-slice BIMM.  Slice outputs are digitally shifted
and accumulated, adding precision without changing the CAM path.  This
supports binary-integer MatMul and quantized int2/int4/int8."

We reuse the packed-popcount kernel per slice: a {0,1} bit-plane p maps to
±1 as p± = 2p − 1, and for x ∈ {−1,+1}^d

    x · p = (x · p± + x · 1) / 2            (x·1 = row sum of x)

so each slice costs exactly one BA-CAM search plus a shared row-sum.  The
two's-complement MSB slice enters with weight −2^(bits−1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bacam import pack_bits
from repro.kernels.bacam_mvm import bacam_mvm


@functools.partial(jax.jit, static_argnames=("bits", "block_q", "block_k", "interpret"))
def bitslice_vmm(
    x_pm1: jax.Array,
    w_int: jax.Array,
    *,
    bits: int,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """y = x_pm1 @ w_int^T via per-slice BA-CAM searches.

    x_pm1: (B, R, d) in {−1,+1}; w_int: (B, N, d) ints in
    [−2^(bits−1), 2^(bits−1)).  Returns (B, R, N) int32 — exact.

    R/N must be multiples of the block sizes (ops.py pads).
    """
    b, r, d = x_pm1.shape
    n = w_int.shape[1]
    xp = pack_bits(x_pm1)
    row_sum = x_pm1.astype(jnp.int32).sum(axis=-1)[:, :, None]  # x·1, shared

    u = w_int.astype(jnp.int32).astype(jnp.uint32)
    out = jnp.zeros((b, r, n), jnp.int32)
    for s in range(bits):  # static: one BA-CAM pass per slice
        plane = ((u >> s) & jnp.uint32(1)).astype(jnp.int32)
        pp = pack_bits(plane)  # pack_bits keys on (value > 0)
        dot_pm = bacam_mvm(
            xp, pp, d=d, block_q=block_q, block_k=block_k, interpret=interpret
        )  # x · p±
        dot01 = (dot_pm + row_sum) // 2  # x · p  (exact: same parity)
        weight = -(1 << s) if s == bits - 1 else (1 << s)
        out = out + weight * dot01
    return out
