"""Pallas TPU kernel: BA-CAM binary matrix-vector/matrix multiply.

Computes signed binary attention scores  s = d - 2*popcount(q ^ k)  from
bit-packed operands.  This is the TPU-native dual of the paper's BA-CAM
array (DESIGN.md §2): the charge-sharing matchline becomes XNOR +
``lax.population_count`` over uint32 lanes; CAM array tiling (Fig. 4 steps
①-④) becomes the BlockSpec grid, with the horizontal-tile concatenation
realized by the (i, j) output grid and the vertical-tile accumulation
register realized by the in-register accumulation over packed words.

Memory layout is the point: keys are stored 1 bit/element (uint32-packed),
so a (Skv, d) key matrix streams HBM->VMEM at 1/16 the bytes of bf16 —
the kernel is *compute*-dominated on the VPU rather than bandwidth-
dominated, mirroring how the analog array removes the memory bottleneck.

VMEM budget (TPU v5e, 128-aligned): default blocks bq=256, bk=512, W<=8:
  q: 256*8*4 B = 8 KiB, k: 512*8*4 B = 16 KiB, acc: 256*512*4 B = 512 KiB
  + out block 512 KiB  =>  ~1 MiB of 16 MiB VMEM  (room for double-buffer).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, o_ref, *, d: int, words: int):
    """One (bq, bk) output tile: accumulate popcounts over packed words."""
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]
    acc = jnp.zeros((bq, bk), jnp.int32)
    for w in range(words):  # static unroll: words = d/32 in {2,4,8}
        x = jnp.bitwise_xor(q_ref[0, :, w][:, None], k_ref[0, :, w][None, :])
        acc = acc + jax.lax.population_count(x).astype(jnp.int32)
    o_ref[0] = jnp.int32(d) - 2 * acc


@functools.partial(
    jax.jit, static_argnames=("d", "block_q", "block_k", "interpret")
)
def bacam_mvm(
    q_packed: jax.Array,
    k_packed: jax.Array,
    *,
    d: int,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Binary scores (B, R, Skv) int32 from packed (B, R, W)/(B, Skv, W).

    R and Skv must be multiples of the block sizes (ops.py pads).
    """
    b, r, words = q_packed.shape
    skv = k_packed.shape[1]
    assert words * 32 == d, (words, d)
    assert r % block_q == 0 and skv % block_k == 0, (r, skv, block_q, block_k)
    grid = (b, r // block_q, skv // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, d=d, words=words),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, words), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_k, words), lambda b_, i, j: (b_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, block_k), lambda b_, i, j: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, r, skv), jnp.int32),
        interpret=interpret,
    )(q_packed, k_packed)
