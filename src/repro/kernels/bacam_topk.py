"""Pallas TPU kernel: fused BA-CAM scoring + stage-1 hierarchical top-k.

This fuses the paper's *Association* stage exactly as the hardware pipelines
it (Sec. III-B1): while the BA-CAM scans key tiles, a bitonic top-2 keeps the
best `stage1_k` scores per tile of `group_size`(=CAM_H=16) keys, and ONLY the
candidates leave the stage.  On TPU the same fusion is a memory-traffic
optimization: the (R, Skv) score matrix never reaches HBM — per key-group
only `stage1_k` (value, index) pairs are written, an 8x/16x reduction in
score traffic (2*16/4 bytes per 16 keys vs 64 bytes).

Masking (causal / sliding window / valid-cache-length) is applied in-kernel
from query positions, so the kernel also serves decode (R=1 row per query)
against a partially-filled cache.

VMEM (defaults bq=256, bk=512, W<=8): scores acc 512 KiB + operands ~24 KiB
+ candidate blocks (256 x 64 x 4 B x 2) 128 KiB  =>  < 1 MiB of 16 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import MASKED_SCORE


def score_and_stage1(q_words, k_words, ok, *, d, group, stage1_k,
                     base_offset):
    """Shared kernel-body block: BA-CAM scoring + stage-1 top-k.

    Used by both the contiguous (this module) and the paged
    (bacam_decode.py) association kernels so the tie-breaking and masking
    semantics can never diverge.

    q_words: (R, W) uint32; k_words: (S, W) uint32; ok: (R, S) bool
    validity mask (the caller's mask source is the only difference
    between the kernels); base_offset: global index of k_words[0].

    Returns (vals, idx): (R, S/group * stage1_k) int32 — per group the
    top stage1_k masked scores (MASKED_SCORE when invalid) and their
    global key indices, group-major / top-k-minor.
    """
    rows, words = q_words.shape
    bk = k_words.shape[0]

    # --- BA-CAM scoring (see bacam_mvm.py) ---
    acc = jnp.zeros((rows, bk), jnp.int32)
    for w in range(words):  # static unroll: words = d/32
        x = jnp.bitwise_xor(q_words[:, w][:, None], k_words[:, w][None, :])
        acc = acc + jax.lax.population_count(x).astype(jnp.int32)
    scores = jnp.where(ok, jnp.int32(d) - 2 * acc, MASKED_SCORE)

    # --- stage-1 top-k per group of `group` keys (bitonic top-2 dual) ---
    ngroups = bk // group
    sg = scores.reshape(rows, ngroups, group)
    gidx = jax.lax.broadcasted_iota(jnp.int32, (rows, ngroups, group), 2)
    vals, idxs = [], []
    cur = sg
    for _ in range(stage1_k):  # sequential max-extraction == stable top-k
        m = cur.max(axis=-1)
        am = jnp.argmax(cur, axis=-1).astype(jnp.int32)
        vals.append(m)
        idxs.append(am)
        cur = jnp.where(gidx == am[..., None], MASKED_SCORE, cur)
    v = jnp.stack(vals, axis=-1).reshape(rows, ngroups * stage1_k)
    base = (base_offset
            + jax.lax.broadcasted_iota(jnp.int32, (rows, ngroups), 1) * group)
    gi = jnp.stack([base + a for a in idxs], axis=-1).reshape(
        rows, ngroups * stage1_k)
    return v, gi


def _kernel(
    q_ref,
    k_ref,
    pos_ref,
    kvlen_ref,
    vals_ref,
    idx_ref,
    *,
    d: int,
    group: int,
    stage1_k: int,
    block_k: int,
    causal: bool,
    window: int | None,
):
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]
    j = pl.program_id(2)

    # --- masking from positions (matchline "search enable" in hardware) ---
    kpos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    qpos = pos_ref[0][:, None]
    ok = kpos < kvlen_ref[0, 0]
    if causal:
        ok = jnp.logical_and(ok, kpos <= qpos)
    if window is not None:
        ok = jnp.logical_and(ok, kpos > qpos - window)

    vals_ref[0], idx_ref[0] = score_and_stage1(
        q_ref[0], k_ref[0], ok, d=d, group=group, stage1_k=stage1_k,
        base_offset=j * block_k)


@functools.partial(
    jax.jit,
    static_argnames=(
        "d", "group", "stage1_k", "causal", "window", "block_q", "block_k", "interpret",
    ),
)
def bacam_topk_stage1(
    q_packed: jax.Array,
    k_packed: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    d: int,
    group: int = 16,
    stage1_k: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """Fused binary scores + stage-1 top-k.

    Args:
      q_packed: (B, R, W) uint32;  k_packed: (B, Skv, W) uint32.
      q_pos: (B, R) int32 query positions (masking); kv_len: (B, 1) int32
        number of valid keys (rest of the padded cache is masked).

    Returns:
      (cand_vals, cand_idx): (B, R, stage1_k*Skv/group) int32; masked
      candidates hold MASKED_SCORE.  Group-major, top-k-minor order
      (matches ref.bacam_topk_stage1_ref).
    """
    b, r, words = q_packed.shape
    skv = k_packed.shape[1]
    assert words * 32 == d
    assert r % block_q == 0 and skv % block_k == 0 and block_k % group == 0
    grid = (b, r // block_q, skv // block_k)
    ncand_blk = stage1_k * (block_k // group)
    ncand = stage1_k * (skv // group)
    kern = functools.partial(
        _kernel,
        d=d, group=group, stage1_k=stage1_k,
        block_k=block_k, causal=causal, window=window,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, words), lambda b_, i, j: (b_, i, 0)),
            pl.BlockSpec((1, block_k, words), lambda b_, i, j: (b_, j, 0)),
            pl.BlockSpec((1, block_q), lambda b_, i, j: (b_, i)),
            pl.BlockSpec((1, 1), lambda b_, i, j: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, ncand_blk), lambda b_, i, j: (b_, i, j)),
            pl.BlockSpec((1, block_q, ncand_blk), lambda b_, i, j: (b_, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, r, ncand), jnp.int32),
            jax.ShapeDtypeStruct((b, r, ncand), jnp.int32),
        ],
        interpret=interpret,
    )(q_packed, k_packed, q_pos, kv_len)
