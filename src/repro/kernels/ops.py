"""Jit'd dispatch wrappers for the Pallas kernels.

These present kernel functionality with framework-friendly shapes (padding,
GQA folding, batch flattening) and select interpret mode automatically:
interpret=True off-TPU (this container), compiled Mosaic on real TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bacam import pack_bits
from repro.core.topk import NEG_INF
from repro.kernels import bacam_decode as _bdec
from repro.kernels import bacam_mvm as _mvm
from repro.kernels import bacam_topk as _btk
from repro.kernels import bitslice_vmm as _bsv
from repro.kernels import flash_attention as _fla
from repro.kernels import paged_flash_decode as _pfd
from repro.kernels.ref import MASKED_SCORE

__all__ = [
    "INTERPRET",
    "bacam_scores",
    "bacam_attention_scores_topk",
    "bacam_attention_scores_topk_packed",
    "bacam_paged_scores_topk",
    "flash_attention",
    "paged_flash_decode",
    "paged_flash_prefill",
    "bitslice_vmm",
    "MASKED_SCORE",
]

INTERPRET = jax.default_backend() != "tpu"


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(n: int, target: int, quantum: int = 8) -> int:
    """Block size: `target` for large inputs, padded-n for small ones."""
    return min(target, _ceil_to(max(n, 1), quantum))


def _pad_axis(x: jax.Array, axis: int, to: int, value=0):
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


def bacam_scores(qb: jax.Array, kb: jax.Array, *, block_q=256, block_k=512) -> jax.Array:
    """Binary scores via the Pallas BA-CAM kernel.

    qb: (B*, R, D) ±1; kb: (B*, Skv, D) ±1 (3-D; callers fold GQA/batch).
    Returns (B*, R, Skv) int32.
    """
    b, r, d = qb.shape
    skv = kb.shape[1]
    bq = _pick_block(r, block_q)
    bk = _pick_block(skv, block_k)
    qp = _pad_axis(pack_bits(qb), 1, _ceil_to(r, bq))
    kp = _pad_axis(pack_bits(kb), 1, _ceil_to(skv, bk))
    s = _mvm.bacam_mvm(qp, kp, d=d, block_q=bq, block_k=bk, interpret=INTERPRET)
    return s[:, :r, :skv]


def bacam_attention_scores_topk(
    qb: jax.Array,
    kb: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    group: int = 16,
    stage1_k: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
):
    """Fused association stage: binary scores + stage-1 top-k candidates.

    qb: (B, R, D) ±1; kb: (B, Skv, D) ±1; q_pos: (B, R) int32;
    kv_len: (B,) or (B, 1) int32.

    Returns (cand_vals f32 with NEG_INF at masked, cand_idx i32), shapes
    (B, R, stage1_k * ceil(Skv/group)).
    """
    d = qb.shape[-1]
    return bacam_attention_scores_topk_packed(
        pack_bits(qb), pack_bits(kb), q_pos, kv_len, d=d,
        group=group, stage1_k=stage1_k, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
    )


def bacam_attention_scores_topk_packed(
    qp: jax.Array,
    kp: jax.Array,
    q_pos: jax.Array,
    kv_len: jax.Array,
    *,
    d: int,
    group: int = 16,
    stage1_k: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 256,
    block_k: int = 512,
):
    """As bacam_attention_scores_topk but on pre-packed uint32 operands
    (the CAMformer KV-cache layout stores keys packed)."""
    b, r, _ = qp.shape
    skv = kp.shape[1]
    bq = _pick_block(r, block_q)
    bk = _pick_block(skv, block_k, quantum=group)
    bk = _ceil_to(bk, group)
    qp = _pad_axis(qp, 1, _ceil_to(r, bq))
    kp = _pad_axis(kp, 1, _ceil_to(skv, bk))
    pos = _pad_axis(q_pos.astype(jnp.int32), 1, _ceil_to(r, bq))
    kvl = jnp.reshape(kv_len.astype(jnp.int32), (b, 1))
    vals, idx = _btk.bacam_topk_stage1(
        qp, kp, pos, kvl,
        d=d, group=group, stage1_k=stage1_k, causal=causal, window=window,
        block_q=bq, block_k=bk, interpret=INTERPRET,
    )
    ncand = stage1_k * (-(-skv // group))
    vals = vals[:, :r, :ncand]
    idx = idx[:, :r, :ncand]
    fvals = jnp.where(vals <= MASKED_SCORE // 2, NEG_INF, vals.astype(jnp.float32))
    return fvals, jnp.minimum(idx, skv - 1)


def bacam_paged_scores_topk(
    qp: jax.Array,
    kp_pages: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_pos: jax.Array | None = None,
    *,
    d: int,
    group: int = 16,
    stage1_k: int = 2,
    window: int | None = None,
):
    """Fused paged decode association stage (see bacam_decode.py).

    qp: (B, H_kv, R, W) uint32 decode rows; kp_pages: (P, H_kv, page, W)
    uint32 pool; page_table: (B, NP) int32; kv_len: (B,) int32; q_pos:
    (B,) int32 per-slot query position (default: kv_len - 1, the decode
    tail).

    Returns (cand_vals f32 with NEG_INF at masked, cand_idx i32 logical
    key indices), shapes (B, H_kv, R, stage1_k * NP*page/group).
    """
    page = kp_pages.shape[2]
    np_ = page_table.shape[1]
    if q_pos is None:
        q_pos = kv_len.reshape(-1) - 1
    vals, idx = _bdec.bacam_paged_topk_stage1(
        qp, kp_pages, page_table, kv_len, q_pos,
        d=d, group=group, stage1_k=stage1_k, window=window,
        interpret=INTERPRET,
    )
    fvals = jnp.where(vals <= MASKED_SCORE // 2, NEG_INF,
                      vals.astype(jnp.float32))
    return fvals, jnp.clip(idx, 0, np_ * page - 1)


def paged_flash_decode(q, k_pages, v_pages, page_table, kv_len, q_pos, *,
                       temp=None, scale=None, binary=False, window=None,
                       interpret=None):
    """Fused paged flash-decode (kernels/paged_flash_decode.py): decode
    attention through the page table with an online softmax — no
    logical-order gather, no (B, H_kv, NP*page, D) scratch.

    q: (B, H, 1, D) decode queries (GQA: H = G * H_kv);
    k_pages/v_pages: (P, H_kv, page, D[v]) one layer's pools;
    page_table: (B, NP) int32; kv_len: (B,) int32; q_pos: (B,) int32.
    temp: (B, H_kv, G) per-row softmax temperature (binary HAD scoring);
    binary: score on sign(q)/sign(k) instead of q·k.

    Dispatch: the compiled Mosaic kernel on TPU; off-TPU the pure-jnp
    streaming walk (ref.paged_flash_decode_ref — same page sweep and
    accumulation order, XLA-compiled) rather than the Pallas
    interpreter, whose per-grid-cell overhead would misrepresent the
    algorithm.  Pass interpret=True to force the Pallas interpreter
    anyway (CPU CI debugging escape hatch).

    Returns (B, H, 1, Dv) in q's dtype; kv_len == 0 rows are zeros.
    """
    from repro.kernels.ref import paged_flash_decode_ref

    b, h, sq, d = q.shape
    assert sq == 1, "paged_flash_decode is the decode (Sq == 1) hot path"
    hkv = k_pages.shape[1]
    g = h // hkv
    dv = v_pages.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    qr = q.reshape(b, hkv, g, d).astype(jnp.float32)
    if binary:
        qr = jnp.where(qr > 0, 1.0, -1.0)
    # The temperature is per query row: fold it (and the score scale)
    # into the query operand so the stream needs no post-hoc rescale.
    qr = qr * jnp.float32(scale)
    if temp is not None:
        qr = qr * temp.reshape(b, hkv, g, 1).astype(jnp.float32)
    if interpret is not None or not INTERPRET:
        # explicit interpret=True/False forces the Pallas kernel in that
        # mode; interpret=None on TPU runs it compiled
        out = _pfd.paged_flash_decode(
            qr, k_pages, v_pages, page_table, kv_len.reshape(b),
            q_pos.reshape(b), binary=binary, window=window,
            interpret=bool(interpret) if interpret is not None else False)
    else:
        out = paged_flash_decode_ref(  # off-TPU default: the jnp walk
            qr, k_pages, v_pages, page_table, kv_len.reshape(b),
            q_pos.reshape(b), binary=binary, window=window)
    return out.reshape(b, h, 1, dv).astype(q.dtype)


def paged_flash_prefill(q, k_pages, v_pages, page_table, kv_len, q_pos, *,
                        temp=None, scale=None, binary=False, window=None,
                        interpret=None):
    """Fused paged flash attention for Sq > 1 chunk rows — the chunked
    continuous-prefill and speculative-verify hot path.  Same kernel
    skeleton as ``paged_flash_decode`` (scalar-prefetched page-table
    walk, online-softmax VMEM scratch, dead-tile skip) with the chunk
    folded into the row axis and a per-row causal anchor.

    q: (B, H, Sq, D) chunk queries (GQA: H = G * H_kv);
    k_pages/v_pages: (P, H_kv, page, D[v]) one layer's pools;
    page_table: (B, NP) int32; kv_len: (B,) int32 post-write extent
    INCLUDING the chunk; q_pos: (B,) int32 — the chunk's FIRST position
    per slot (the scheduler's ``offsets``), row s anchors at q_pos + s.
    temp: (B, H_kv, G * Sq) per-row softmax temperature (binary HAD
    scoring; under spec_verify these are the sequential per-query
    running-k_scale values from ``_chunk_scale_seq``) — per-row, so it
    folds into the query operand and the kernel needs no spec awareness.
    binary: score on sign(q)/sign(k) instead of q·k.

    Dispatch triad as ``paged_flash_decode``: compiled Mosaic on TPU,
    the jnp streaming walk off-TPU (identical accumulation order),
    interpret=True forces the Pallas interpreter.

    Returns (B, H, Sq, Dv) in q's dtype; kv_len == 0 rows are zeros.
    """
    from repro.kernels.ref import paged_flash_decode_ref

    b, h, sq, d = q.shape
    hkv = k_pages.shape[1]
    g = h // hkv
    dv = v_pages.shape[-1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    qr = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    if binary:
        qr = jnp.where(qr > 0, 1.0, -1.0)
    qr = qr * jnp.float32(scale)
    if temp is not None:
        # (B, H_kv, G*Sq) row-major (g, s) — matches the row fold below
        qr = qr * temp.reshape(b, hkv, g, sq, 1).astype(jnp.float32)
    qr = qr.reshape(b, hkv, g * sq, d)  # row r = g_idx * sq + s
    if interpret is not None or not INTERPRET:
        out = _pfd.paged_flash_decode(
            qr, k_pages, v_pages, page_table, kv_len.reshape(b),
            q_pos.reshape(b), sq=sq, binary=binary, window=window,
            interpret=bool(interpret) if interpret is not None else False)
    else:
        out = paged_flash_decode_ref(  # off-TPU default: the jnp walk
            qr, k_pages, v_pages, page_table, kv_len.reshape(b),
            q_pos.reshape(b), sq=sq, binary=binary, window=window)
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def flash_attention(q, k, v, q_offset=0, *, causal=True, window=None, scale=None,
                    block_q=512, block_k=512):
    """Dense flash attention; q: (B*, Sq, D), k/v: (B*, Skv, D)."""
    b, sq, d = q.shape
    skv = k.shape[1]
    bq = _pick_block(sq, block_q)
    bk = _pick_block(skv, block_k)
    qq = _pad_axis(q, 1, _ceil_to(sq, bq))
    kk = _pad_axis(k, 1, _ceil_to(skv, bk))
    vv = _pad_axis(v, 1, _ceil_to(skv, bk))
    # Padded keys are masked because their kpos >= skv > every real qpos
    # only under causal; for non-causal we must mask explicitly via window
    # trick — instead pad K with +inf-distance: set padded kpos invalid by
    # passing kv length through the causal offset. Simplest robust route:
    # pad then slice, masking padded keys via a large negative bias on V=0
    # and K=0 — K=0 gives logits 0 which would leak. So: only allow padding
    # under causal=True or when skv is already aligned.
    if kk.shape[1] != skv and not causal:
        raise ValueError("non-causal flash requires Skv % block_k == 0")
    out = _fla.flash_attention(
        qq, kk, vv, q_offset, causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=INTERPRET,
    )
    return out[:, :sq]


def bitslice_vmm(x_pm1, w_int, *, bits, block_q=256, block_k=512):
    """Exact int VMM via bit slicing; x: (B,R,d) ±1, w_int: (B,N,d)."""
    b, r, d = x_pm1.shape
    n = w_int.shape[1]
    bq = _pick_block(r, block_q)
    bk = _pick_block(n, block_k)
    x = _pad_axis(x_pm1, 1, _ceil_to(r, bq), value=1)
    w = _pad_axis(w_int, 1, _ceil_to(n, bk))
    y = _bsv.bitslice_vmm(x, w, bits=bits, block_q=bq, block_k=bk, interpret=INTERPRET)
    return y[:, :r, :n]
