"""Pallas TPU kernels for CAMformer hot spots + jnp oracles.

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU in interpret mode.  See ops.py for dispatch wrappers and
ref.py for the oracles.
"""

from repro.kernels import ops, ref
from repro.kernels.bacam_decode import bacam_paged_topk_stage1
from repro.kernels.bacam_mvm import bacam_mvm
from repro.kernels.bacam_topk import bacam_topk_stage1
from repro.kernels.bitslice_vmm import bitslice_vmm
from repro.kernels.flash_attention import flash_attention

__all__ = [
    "ops",
    "ref",
    "bacam_mvm",
    "bacam_paged_topk_stage1",
    "bacam_topk_stage1",
    "bitslice_vmm",
    "flash_attention",
]
