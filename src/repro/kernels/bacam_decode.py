"""Pallas TPU kernel: fused paged CAM decode (scoring + stage-1 top-k).

Decode-time association against the serving engine's *paged*, bit-packed
KV cache (serving/kv_cache.py): keys live in fixed-size physical pages of
``(H_kv, page_size, d/32)`` uint32 words, and each sequence's logical order
is given by a page table.  The page table is a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``), so the grid walks *logical* pages and
the BlockSpec index_map dereferences ``page_table[b, j]`` to DMA the right
physical page — the classic paged-attention gather, but over 1-bit keys.

Per (slot, kv-head, logical page) grid cell the kernel fuses:

  * BA-CAM scoring: popcount(q ^ k) over packed words — the (R, Skv) score
    matrix never exists in HBM (R = GQA group size rows per kv head);
  * masking from the slot's kv length (matchline "search enable");
  * stage-1 hierarchical top-k per group of ``group``(=CAM_H=16) keys.

Only ``stage1_k * page_size/group`` (value, index) candidate pairs leave
each page; stage-2 top-k + softmax + sparse-V contextualization run on that
tiny candidate set (core/attention.camformer_paged_attention).

Inactive slots point every page-table entry at the reserved trash page 0;
their scores are fully masked by ``kv_len`` so the garbage never surfaces.

VMEM per cell (defaults page=64, W<=8, R<=8): q 256 B + k 2 KiB + scores
R*64*4 B ~ 2 KiB + candidates ~KiB  =>  trivially resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bacam_topk import score_and_stage1


def _kernel(
    pt_ref,
    kvlen_ref,
    qpos_ref,
    q_ref,
    k_ref,
    vals_ref,
    idx_ref,
    *,
    d: int,
    group: int,
    stage1_k: int,
    page: int,
    window: int | None,
):
    b = pl.program_id(0)
    j = pl.program_id(2)  # logical page index
    rows = q_ref.shape[2]

    # --- masking: validity (kv length) + causality from the slot's query
    # position (matchline "search enable"; decode rows share one qpos) ---
    kvl = kvlen_ref[b]
    qpos = qpos_ref[b]
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1)
    ok = jnp.logical_and(kpos < kvl, kpos <= qpos)
    if window is not None:
        ok = jnp.logical_and(ok, kpos > qpos - window)

    # scoring + stage-1 shared with the contiguous kernel (bacam_topk.py)
    vals_ref[0, 0], idx_ref[0, 0] = score_and_stage1(
        q_ref[0, 0], k_ref[0, 0], ok, d=d, group=group, stage1_k=stage1_k,
        base_offset=j * page)


@functools.partial(
    jax.jit,
    static_argnames=("d", "group", "stage1_k", "window", "interpret"),
)
def bacam_paged_topk_stage1(
    q_packed: jax.Array,
    kp_pages: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_pos: jax.Array,
    *,
    d: int,
    group: int = 16,
    stage1_k: int = 2,
    window: int | None = None,
    interpret: bool = True,
):
    """Fused paged binary scoring + stage-1 top-k for decode rows.

    Args:
      q_packed: (B, H_kv, R, W) uint32 — R = GQA-group query rows per kv
        head, all at one position per slot (decode: kv_len - 1).
      kp_pages: (n_pages, H_kv, page_size, W) uint32 key pool (one layer).
      page_table: (B, NP) int32 — logical->physical page map; unallocated
        entries must hold a valid (trash) page index.
      kv_len: (B,) int32 valid tokens per slot.
      q_pos: (B,) int32 query position per slot (causal/window anchor).

    Returns:
      (cand_vals, cand_idx): (B, H_kv, R, stage1_k * NP*page/group) int32;
      masked candidates hold MASKED_SCORE.  Logical-page-major, group-major,
      top-k-minor order (matches ref.bacam_paged_topk_ref and the ordering
      of core.topk.two_stage_topk over a gathered contiguous cache).
    """
    b, hkv, rows, words = q_packed.shape
    n_pages, _, page, _ = kp_pages.shape
    np_ = page_table.shape[1]
    assert words * 32 == d
    assert page % group == 0
    ncp = stage1_k * (page // group)  # candidates per page
    grid = (b, hkv, np_)
    kern = functools.partial(
        _kernel,
        d=d, group=group, stage1_k=stage1_k,
        page=page, window=window,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # page_table, kv_len, q_pos
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rows, words),
                         lambda b_, h, j, pt, kvl, qp: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, words),
                         lambda b_, h, j, pt, kvl, qp: (pt[b_, j], h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, ncp),
                         lambda b_, h, j, pt, kvl, qp: (b_, h, 0, j)),
            pl.BlockSpec((1, 1, rows, ncp),
                         lambda b_, h, j, pt, kvl, qp: (b_, h, 0, j)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, rows, np_ * ncp), jnp.int32),
            jax.ShapeDtypeStruct((b, hkv, rows, np_ * ncp), jnp.int32),
        ],
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      q_pos.astype(jnp.int32), q_packed, kp_pages)
