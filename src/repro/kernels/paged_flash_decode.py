"""Pallas TPU kernel: fused paged flash-decode for dense & binary scoring.

The digital-contextualization half of the serving stack: decode-time
softmax attention against the engine's *paged* KV pools
(serving/kv_cache.py) without ever gathering a slot's pages into logical
order.  The page table is a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``) — exactly the structure of the CAM
decode kernel (bacam_decode.py) — so the grid walks *logical* pages and
the BlockSpec index_map dereferences ``page_table[b, j]`` to DMA the
right physical K/V page.  Per (slot, kv-head, logical page) grid cell
the kernel fuses:

  * a per-page score tile (R, page) — R = GQA group rows per kv head
    at ONE decode position, or R = G * Sq chunk rows when ``sq > 1``
    (chunked prefill / speculative verify) — via one MXU dot; the
    (R, S_log) score matrix never exists in HBM;
  * masking from the slot's kv length / query position (+ window); for
    ``sq > 1`` the causal anchor is PER ROW: row r = g * sq + s sits at
    position ``q_pos[b] + s`` (chunk positions are contiguous from the
    slot's ``offsets``), which yields the intra-chunk causal mask;
  * an online (streaming) softmax: running max / denominator / output
    accumulator live in VMEM scratch across the page sweep (the
    canonical flash pattern of kernels/flash_attention.py), so there is
    no logical-order K/V gather and no (B, H_kv, NP*page, D) scratch.

ONE kernel skeleton serves both registered softmax realizations
(core/backend.py):

  * ``dense``  — bf16/f32 q·k scores (queries arrive pre-scaled by
    1/sqrt(d));
  * ``binary`` — HAD sign-match scoring (``binary=True``): the K tile is
    binarized in-register with ``core/binarize.sign_pm1`` semantics
    (x > 0 -> +1 else -1) and queries arrive as ±1 rows pre-scaled by
    the HAD softmax temperature (q_scale * running k_scale * 1/sqrt(d)),
    which is per-row and therefore folds into the query operand — the
    stream never needs a post-hoc rescale.

Rows with ``kv_len == 0`` are the fused-step contract's INERT rows:
every score masks away, the denominator stays zero, and the output is
a defined all-zeros vector that the engine never reads.  Inactive
page-table entries point at the reserved trash page 0; kv_len masking
keeps its garbage out of every live row's softmax.

Interpret-mode escape hatch: pass ``interpret=True`` (the ops wrapper
does this automatically off-TPU) to run the kernel through the Pallas
interpreter for CPU CI debugging — same semantics, XLA-compiled grid.

VMEM per cell (defaults page=64, D<=256, R<=8): k/v tiles
2*64*256*4 B = 128 KiB + q/acc ~ 2*8*256*4 B ~ 16 KiB  =>  resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.topk import NEG_INF


def _kernel(
    pt_ref,
    kvlen_ref,
    qpos_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    page: int,
    sq: int,
    binary: bool,
    window: int | None,
):
    b = pl.program_id(0)
    j = pl.program_id(2)  # logical page index
    nj = pl.num_programs(2)
    rows = q_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kvl = kvlen_ref[b]
    qpos = qpos_ref[b]

    # Dead tiles — logical pages at/after the slot's kv extent — are
    # skipped outright: the index_map clamps them onto the last LIVE
    # page (consecutive identical block indices, so the pipeline elides
    # the page DMA instead of fetching trash) and this guard elides the
    # compute.  A skipped tile leaves the streaming state untouched,
    # which is exactly what the old fetch-then-mask update reduced to
    # (all-NEG_INF scores: alpha = 1, p = 0).
    @pl.when(j * page < kvl)
    def _live_tile():
        # --- per-page score tile (R, page): one MXU dot, not in HBM ---
        q = q_ref[0, 0].astype(jnp.float32)  # (R, D) pre-scaled rows
        k = k_ref[0, 0].astype(jnp.float32)  # (page, D) physical tile
        if binary:
            kb = jnp.where(k > 0, 1.0, -1.0)  # sign_pm1 in-register
        else:
            kb = k
        s = jax.lax.dot_general(
            q, kb, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

        # --- masking: validity (kv length) + causality.  Decode
        # (sq == 1) rows share one qpos per slot; sq > 1 chunk rows are
        # causal PER ROW — row r = g * sq + s anchors at qpos + s, the
        # intra-chunk mask keyed on the slot's chunk offset ---
        kpos = (j * page
                + jax.lax.broadcasted_iota(jnp.int32, (rows, page), 1))
        if sq > 1:
            qrow = qpos + jax.lax.broadcasted_iota(
                jnp.int32, (rows, page), 0) % sq
        else:
            qrow = qpos
        ok = jnp.logical_and(kpos < kvl, kpos <= qrow)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qrow - window)
        s = jnp.where(ok, s, NEG_INF)

        # --- online softmax update (flash_attention.py pattern) ---
        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)  # fully-masked rows stay all-zero
        l_scr[:, 0] = l_scr[:, 0] * alpha + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[:, 0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sq", "binary", "window", "interpret"))
def paged_flash_decode(
    q_rows: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_pos: jax.Array,
    *,
    sq: int = 1,
    binary: bool = False,
    window: int | None = None,
    interpret: bool = True,
):
    """Fused paged flash-decode over one layer's K/V page pools.

    Args:
      q_rows: (B, H_kv, R, D) float32 — R = GQA-group query rows per kv
        head, PRE-SCALED: dense rows carry q * 1/sqrt(d); binary rows
        carry sign(q) * temp * 1/sqrt(d) (the HAD temperature — per-slot
        running k_scale, or sequential per-query scales under
        spec_verify — is per-row, so it folds into the operand).  For
        ``sq == 1`` all R rows share the slot's decode position; for
        ``sq > 1`` (chunked prefill / speculative verify) R = G * Sq
        with row r = g * sq + s at position ``q_pos[b] + s``.
      k_pages: (P, H_kv, page, D) key pool (one layer; bf16/f32).
      v_pages: (P, H_kv, page, Dv) value pool.
      page_table: (B, NP) int32 logical->physical page map; unallocated
        entries must hold a valid (trash) page index.
      kv_len: (B,) int32 valid tokens per slot (0 = inert row).  Under
        ``sq > 1`` this is the post-write extent INCLUDING the chunk.
      q_pos: (B,) int32 decode position per slot — for ``sq > 1`` the
        chunk's FIRST position (the slot's ``offsets``).
      sq: chunk length folded into the row axis (static).
      binary: binarize the K tile in-register (HAD sign-match scoring).
      interpret: run via the Pallas interpreter (CPU CI escape hatch).

    Returns:
      (B, H_kv, R, Dv) float32 attention outputs; inert rows are zeros.
    """
    b, hkv, rows, d = q_rows.shape
    n_pages, _, page, dv = v_pages.shape
    np_ = page_table.shape[1]
    assert k_pages.shape[:3] == (n_pages, hkv, page), (
        k_pages.shape, v_pages.shape)
    assert rows % sq == 0, (rows, sq)
    grid = (b, hkv, np_)
    kern = functools.partial(
        _kernel, page=page, sq=sq, binary=binary, window=window)

    def _kv_map(b_, h, j, pt, kvl, qp):
        # Dead logical pages (at/after the kv extent) clamp onto the
        # slot's last LIVE page: the block index repeats, so the Pallas
        # pipeline skips the redundant DMA and the kernel's `@pl.when`
        # guard skips the compute — trash-extent tiles cost nothing.
        last = jnp.maximum((kvl[b_] - 1) // page, 0)
        return (pt[b_, jnp.where(j * page < kvl[b_], j, last)], h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # page_table, kv_len, q_pos
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda b_, h, j, pt, kvl, qp: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), _kv_map),
            pl.BlockSpec((1, 1, page, dv), _kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, rows, dv),
                         lambda b_, h, j, pt, kvl, qp: (b_, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, 1), jnp.float32),   # running max
            pltpu.VMEM((rows, 1), jnp.float32),   # running denominator
            pltpu.VMEM((rows, dv), jnp.float32),  # output accumulator
        ],
    )
    (out,) = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((b, hkv, rows, dv), jnp.float32)],
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32),
      q_pos.astype(jnp.int32), q_rows.astype(jnp.float32), k_pages, v_pages)
    return out
