"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-exact (or numerically-reference) semantics the
kernels are tested against (tests/test_kernels.py sweeps shapes/dtypes and
asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topk import NEG_INF

MASKED_SCORE = -(2**30)  # integer "minus infinity" for masked binary scores


def bacam_scores_ref(q_packed: jax.Array, k_packed: jax.Array, d: int) -> jax.Array:
    """Binary QK^T from packed operands: s = d - 2*popcount(q ^ k).

    q_packed: (B, R, W) uint32;  k_packed: (B, Skv, W) uint32.
    Returns (B, R, Skv) int32.
    """
    x = jnp.bitwise_xor(q_packed[:, :, None, :], k_packed[:, None, :, :])
    mism = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    return jnp.int32(d) - 2 * mism


def masked_scores_ref(
    scores: jax.Array,
    q_pos: jax.Array,
    *,
    causal: bool,
    window: int | None,
    kv_len: jax.Array | int,
) -> jax.Array:
    """Apply causal/window/validity masking with the integer sentinel."""
    b, r, skv = scores.shape
    kpos = jnp.arange(skv, dtype=jnp.int32)[None, None, :]
    qpos = q_pos[:, :, None]
    ok = kpos < jnp.asarray(kv_len, jnp.int32).reshape(-1, 1, 1)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, scores, MASKED_SCORE)


def bacam_topk_stage1_ref(
    q_packed: jax.Array,
    k_packed: jax.Array,
    d: int,
    q_pos: jax.Array,
    *,
    group_size: int = 16,
    stage1_k: int = 2,
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | int | None = None,
):
    """Oracle for the fused score + stage-1 top-k kernel.

    Returns (cand_vals, cand_idx): (B, R, stage1_k * Skv/group) int32 —
    per group of `group_size` keys the top `stage1_k` masked scores and
    their global key indices, groups in order (hardware tile order).
    """
    b, r, _ = q_packed.shape
    skv = k_packed.shape[1]
    if kv_len is None:
        kv_len = skv
    s = bacam_scores_ref(q_packed, k_packed, d)
    s = masked_scores_ref(s, q_pos, causal=causal, window=window, kv_len=kv_len)
    groups = skv // group_size
    sg = s.reshape(b, r, groups, group_size)
    v, i = jax.lax.top_k(sg, stage1_k)  # (B,R,G,s1)
    gi = i.astype(jnp.int32) + (jnp.arange(groups, dtype=jnp.int32) * group_size)[
        None, None, :, None
    ]
    return v.reshape(b, r, groups * stage1_k), gi.reshape(b, r, groups * stage1_k)


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, scale=None, window=None):
    """Naive softmax attention, (B, S, D) per-head layout.

    q: (B, Sq, D); k,v: (B, Skv, D).  q row i has position q_offset + i.
    """
    b, sq, d = q.shape
    skv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq, dtype=jnp.int32)[:, None] + q_offset
    kpos = jnp.arange(skv, dtype=jnp.int32)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def bitslice_vmm_ref(x: jax.Array, w_int: jax.Array, bits: int) -> jax.Array:
    """Oracle for bit-sliced binary-integer VMM:  y = x @ w_int.

    x: (B, R, d) in {-1,+1}; w_int: (B, N, d) signed ints representable in
    `bits` two's-complement bits.  Returns (B, R, N) int32 — exact.
    """
    return jnp.einsum(
        "brd,bnd->brn", x.astype(jnp.int32), w_int.astype(jnp.int32)
    )


def int_slices(w_int: jax.Array, bits: int) -> jax.Array:
    """Two's-complement bit planes of w_int: (bits, ...) uint32 in {0,1}."""
    u = w_int.astype(jnp.int32).astype(jnp.uint32)
    return jnp.stack([(u >> s) & jnp.uint32(1) for s in range(bits)], axis=0)
