"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the bit-exact (or numerically-reference) semantics the
kernels are tested against (tests/test_kernels.py sweeps shapes/dtypes and
asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.topk import NEG_INF

MASKED_SCORE = -(2**30)  # integer "minus infinity" for masked binary scores


def bacam_scores_ref(q_packed: jax.Array, k_packed: jax.Array, d: int) -> jax.Array:
    """Binary QK^T from packed operands: s = d - 2*popcount(q ^ k).

    q_packed: (B, R, W) uint32;  k_packed: (B, Skv, W) uint32.
    Returns (B, R, Skv) int32.
    """
    x = jnp.bitwise_xor(q_packed[:, :, None, :], k_packed[:, None, :, :])
    mism = jax.lax.population_count(x).astype(jnp.int32).sum(-1)
    return jnp.int32(d) - 2 * mism


def masked_scores_ref(
    scores: jax.Array,
    q_pos: jax.Array,
    *,
    causal: bool,
    window: int | None,
    kv_len: jax.Array | int,
) -> jax.Array:
    """Apply causal/window/validity masking with the integer sentinel."""
    b, r, skv = scores.shape
    kpos = jnp.arange(skv, dtype=jnp.int32)[None, None, :]
    qpos = q_pos[:, :, None]
    ok = kpos < jnp.asarray(kv_len, jnp.int32).reshape(-1, 1, 1)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    return jnp.where(ok, scores, MASKED_SCORE)


def bacam_topk_stage1_ref(
    q_packed: jax.Array,
    k_packed: jax.Array,
    d: int,
    q_pos: jax.Array,
    *,
    group_size: int = 16,
    stage1_k: int = 2,
    causal: bool = True,
    window: int | None = None,
    kv_len: jax.Array | int | None = None,
):
    """Oracle for the fused score + stage-1 top-k kernel.

    Returns (cand_vals, cand_idx): (B, R, stage1_k * Skv/group) int32 —
    per group of `group_size` keys the top `stage1_k` masked scores and
    their global key indices, groups in order (hardware tile order).
    """
    b, r, _ = q_packed.shape
    skv = k_packed.shape[1]
    if kv_len is None:
        kv_len = skv
    s = bacam_scores_ref(q_packed, k_packed, d)
    s = masked_scores_ref(s, q_pos, causal=causal, window=window, kv_len=kv_len)
    groups = skv // group_size
    sg = s.reshape(b, r, groups, group_size)
    v, i = jax.lax.top_k(sg, stage1_k)  # (B,R,G,s1)
    gi = i.astype(jnp.int32) + (jnp.arange(groups, dtype=jnp.int32) * group_size)[
        None, None, :, None
    ]
    return v.reshape(b, r, groups * stage1_k), gi.reshape(b, r, groups * stage1_k)


def paged_gather_ref(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather a paged pool into per-slot contiguous logical order.

    THE test oracle for every paged-decode kernel in this package
    (bacam_decode.py, paged_flash_decode.py) and the runtime
    ``paged_impl="gather"`` reference realization: logical position p is
    row p of the gather, so the contiguous-cache attend/masking
    semantics apply verbatim to its output, and each fused kernel is
    pinned token-for-token against an attend over this layout.  Note it
    materializes the full (B, H_kv, NP * page_size, ...) table extent —
    exactly the O(slots x max_len x d) scratch the fused kernels exist
    to avoid.

    pages: (n_pages, H_kv, page_size, ...); page_table: (B, NP) int32.
    Returns (B, H_kv, NP * page_size, ...) — slot-major logical layout.
    """
    g = pages[page_table]  # (B, NP, H_kv, page, ...)
    b, np_, hkv, page = g.shape[:4]
    g = jnp.moveaxis(g, 2, 1)  # (B, H_kv, NP, page, ...)
    return g.reshape(b, hkv, np_ * page, *g.shape[4:])


def paged_flash_decode_ref(
    q_rows: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_pos: jax.Array,
    *,
    sq: int = 1,
    binary: bool = False,
    window: int | None = None,
) -> jax.Array:
    """Pure-jnp oracle for kernels/paged_flash_decode.py — AND its
    off-TPU realization (kernels/ops.py dispatches here when no TPU is
    present, where the Pallas interpreter's per-grid-cell overhead would
    misrepresent the streaming algorithm).

    Walks the page list mirroring the kernel's grid sweep — one
    (B, H_kv, page, D) tile per step, online-softmax running
    max/denominator/accumulator, the kernel's exact accumulation order —
    so, like the kernel and unlike ``paged_gather_ref``, it never
    materializes the logical-order K/V scratch.  Short tables (serving
    decode: a handful of pages) unroll the sweep so XLA fuses the steps;
    long tables fall back to ``lax.scan``.  Shapes/semantics as the
    kernel: q_rows (B, H_kv, R, D) PRE-SCALED rows (for ``sq > 1`` chunk
    attends R = G * Sq with row r = g * sq + s causally anchored at
    ``q_pos[b] + s``), returns (B, H_kv, R, Dv) float32, ``kv_len == 0``
    rows are zeros.
    """
    from repro.core.topk import NEG_INF

    b, hkv, rows, d = q_rows.shape
    _, _, page, dv = v_pages.shape
    np_ = page_table.shape[1]
    q = q_rows.astype(jnp.float32)
    kvl = kv_len.reshape(b, 1, 1, 1)
    qp = q_pos.reshape(b, 1, 1, 1)
    if sq > 1:  # per-row intra-chunk causal anchors, as the kernel
        qp = qp + (jnp.arange(rows, dtype=jnp.int32) % sq).reshape(
            1, 1, rows, 1)

    def step(carry, j):
        m, denom, acc = carry
        phys = page_table[:, j]  # (B,)
        k = k_pages[phys].astype(jnp.float32)  # (B, H_kv, page, D)
        v = v_pages[phys].astype(jnp.float32)
        if binary:
            k = jnp.where(k > 0, 1.0, -1.0)  # sign_pm1 semantics
        s = jnp.einsum("bhrd,bhpd->bhrp", q, k)
        kpos = j * page + jnp.arange(page, dtype=jnp.int32)[None, None, None]
        ok = (kpos < kvl) & (kpos <= qp)
        if window is not None:
            ok = ok & (kpos > qp - window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(ok, jnp.exp(s - m_new[..., None]), 0.0)
        denom = denom * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhrp,bhpd->bhrd", p, v)
        return (m_new, denom, acc), None

    carry = (jnp.full((b, hkv, rows), NEG_INF, jnp.float32),
             jnp.zeros((b, hkv, rows), jnp.float32),
             jnp.zeros((b, hkv, rows, dv), jnp.float32))
    if np_ <= 32:  # unroll: fusable steps, no loop overhead
        for j in range(np_):
            carry, _ = step(carry, jnp.int32(j))
    else:
        carry, _ = jax.lax.scan(step, carry,
                                jnp.arange(np_, dtype=jnp.int32))
    m, denom, acc = carry
    return acc / jnp.maximum(denom, 1e-30)[..., None]


def bacam_paged_topk_ref(
    q_packed: jax.Array,
    kp_pages: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    d: int,
    *,
    q_pos: jax.Array | None = None,
    group_size: int = 16,
    stage1_k: int = 2,
    window: int | None = None,
):
    """Oracle for the fused paged decode kernel (bacam_decode.py).

    q_packed: (B, H_kv, R, W); kp_pages: (P, H_kv, page, W);
    page_table: (B, NP); kv_len: (B,); q_pos: (B,) query position per
    slot (default kv_len - 1, the decode tail).  Returns
    (cand_vals, cand_idx) of shape (B, H_kv, R, stage1_k * NP*page/group)
    int32, logical-page-major.
    """
    b, hkv, r, w = q_packed.shape
    kp = paged_gather_ref(kp_pages, page_table)  # (B, H_kv, S_log, W)
    s_log = kp.shape[2]
    s = bacam_scores_ref(
        q_packed.reshape(b * hkv, r, w), kp.reshape(b * hkv, s_log, w), d)
    if q_pos is None:
        q_pos = kv_len - 1
    qpos = jnp.broadcast_to(q_pos[:, None, None], (b, hkv, r))
    kvl = jnp.broadcast_to(kv_len[:, None], (b, hkv)).reshape(b * hkv)
    s = masked_scores_ref(
        s, qpos.reshape(b * hkv, r), causal=True, window=window, kv_len=kvl)
    groups = s_log // group_size
    sg = s.reshape(b * hkv, r, groups, group_size)
    v, i = jax.lax.top_k(sg, stage1_k)
    gi = i.astype(jnp.int32) + (
        jnp.arange(groups, dtype=jnp.int32) * group_size)[None, None, :, None]
    ncand = groups * stage1_k
    return (v.reshape(b, hkv, r, ncand), gi.reshape(b, hkv, r, ncand))


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, scale=None, window=None):
    """Naive softmax attention, (B, S, D) per-head layout.

    q: (B, Sq, D); k,v: (B, Skv, D).  q row i has position q_offset + i.
    """
    b, sq, d = q.shape
    skv = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq, dtype=jnp.int32)[:, None] + q_offset
    kpos = jnp.arange(skv, dtype=jnp.int32)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)


def bitslice_vmm_ref(x: jax.Array, w_int: jax.Array, bits: int) -> jax.Array:
    """Oracle for bit-sliced binary-integer VMM:  y = x @ w_int.

    x: (B, R, d) in {-1,+1}; w_int: (B, N, d) signed ints representable in
    `bits` two's-complement bits.  Returns (B, R, N) int32 — exact.
    """
    return jnp.einsum(
        "brd,bnd->brn", x.astype(jnp.int32), w_int.astype(jnp.int32)
    )


def int_slices(w_int: jax.Array, bits: int) -> jax.Array:
    """Two's-complement bit planes of w_int: (bits, ...) uint32 in {0,1}."""
    u = w_int.astype(jnp.int32).astype(jnp.uint32)
    return jnp.stack([(u >> s) & jnp.uint32(1) for s in range(bits)], axis=0)
