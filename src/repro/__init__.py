"""CAMformer reproduction package.

Process-wide jax configuration lives here so every entry point (tests,
launchers, benchmarks) agrees:

  * ``jax_threefry_partitionable``: newer jax defaults this to True; on the
    0.4.x CI pin it still defaults to False, under which ``jax.random``
    values depend on the output *sharding* — the same seed would initialize
    different weights on different meshes, breaking elastic rescale and the
    sharded==unsharded equivalence tests.  Force the modern behavior.
"""

import jax as _jax

if not _jax.config.jax_threefry_partitionable:
    _jax.config.update("jax_threefry_partitionable", True)
