"""Checkpointing: async, atomic, keep-N, mesh-agnostic (elastic restore).

Layout:  <dir>/step_<k>/   one .npy per flattened leaf + manifest.json.
Writes go to  <dir>/tmp_<k>  and are atomically renamed, so a crash mid-save
never corrupts the latest checkpoint; `latest_step` only sees complete
checkpoints.  Leaves are stored as FULL host arrays (gathered), so a
checkpoint written on one mesh restores onto ANY mesh/sharding — this is
what makes elastic rescaling (launch/elastic.py) and trainer fail-over
work.  Saving runs on a background thread (async checkpointing overlaps
the next training steps); `wait()` joins before the next save or exit.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_SEP = "::"


def _flat(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        items[key] = leaf
    return items, treedef


def save_checkpoint(ckpt_dir: str, state, step: int, keep: int = 3):
    """Synchronous atomic save of a (possibly sharded) pytree."""
    items, _ = _flat(state)
    tmp = os.path.join(ckpt_dir, f"tmp_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for key, leaf in items.items():
        arr = np.asarray(jax.device_get(leaf))  # gathers sharded arrays
        fname = key.replace("/", "_") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(_steps(ckpt_dir))
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)


def _steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_", 1)[1]))
            except ValueError:
                continue
    return out


def latest_step(ckpt_dir: str):
    steps = _steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, state_template, step: int | None = None,
                       shardings=None):
    """Restore onto the CURRENT mesh (shardings tree optional; defaults to
    the template leaves' shardings if they are concrete arrays, else
    unsharded host arrays)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {m["key"]: m for m in manifest["leaves"]}

    items, treedef = _flat(state_template)
    sh_items = _flat(shardings)[0] if shardings is not None else {}
    leaves = []
    for key, tmpl in items.items():
        m = by_key[key]
        arr = np.load(os.path.join(d, m["file"]))
        sh = sh_items.get(key)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, step


class AsyncCheckpointer:
    """Background-thread checkpointing (overlaps training compute)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir, self.keep = ckpt_dir, keep
        self._thread = None
        self.last_error = None

    def save(self, state, step: int):
        self.wait()
        # device_get on the main thread (device consistency), IO on worker
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, host_state, step, self.keep)
            except Exception as e:  # noqa: BLE001 — surfaced via last_error
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
