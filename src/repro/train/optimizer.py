"""AdamW optimizer + schedules (no optax dependency — substrate built here).

Functional API mirroring optax minimally:
    opt = adamw(schedule, ...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
Optimizer state trees share the parameter PartitionSpecs (m/v shard like
their parameters), which launch/steps.py exploits for the dry-run.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "cosine_schedule", "constant_schedule", "global_norm",
           "clip_by_global_norm", "Optimizer"]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state, stats)


def constant_schedule(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)

    return fn


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def adamw(
    schedule: Callable,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(step)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}, {
            "grad_norm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)
