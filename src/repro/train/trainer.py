"""Fault-tolerant training loop.

Features expected at 1000+ node scale, realized at whatever scale the
current mesh provides:

  * auto-resume: picks up the latest complete checkpoint in ckpt_dir.
  * async checkpointing every `ckpt_every` steps (atomic, keep-N).
  * NaN / loss-spike guard: a non-finite loss (SDC, bad node, data bug)
    triggers rollback to the last checkpoint and resumes from there —
    deterministic data means the stream replays identically.
  * straggler monitor: per-step wall time vs a running median; steps slower
    than `straggler_factor` x median are logged with their step index (on a
    real cluster this feeds the scheduler's node-health signal).
  * stateless-resumable data (see train/data.py): no iterator state in the
    checkpoint, elastic-rescale safe.
"""

from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import numpy as np

from repro.launch.steps import make_train_step, state_specs
from repro.models.module import init_params
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    peak_lr: float = 3e-4
    warmup: int = 10
    straggler_factor: float = 2.0
    seed: int = 0


class Trainer:
    def __init__(self, md, cfg, mesh, data, tcfg: TrainerConfig):
        self.md, self.cfg, self.mesh, self.data, self.tcfg = md, cfg, mesh, data, tcfg
        step_fn, self.opt = make_train_step(
            md, cfg, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
            total_steps=tcfg.total_steps)
        self.state_sds, self.state_shard = state_specs(md, cfg, mesh)
        self.step_fn = jax.jit(step_fn,
                               in_shardings=(self.state_shard, None),
                               out_shardings=None,
                               donate_argnums=(0,))
        self.ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep)
        self.metrics_log = []
        self.events = []  # (step, kind, detail) — stragglers, rollbacks, ...

    # ------------------------------------------------------------------
    def init_state(self):
        params = jax.jit(
            lambda key: init_params(self.md.specs(self.cfg), key),
            out_shardings=self.state_shard["params"],
        )(jax.random.PRNGKey(self.tcfg.seed))
        opt_state = jax.jit(
            self.opt.init, out_shardings=self.state_shard["opt"],
        )(params)
        return {"params": params, "opt": opt_state}

    def restore_or_init(self):
        last = latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return self.init_state(), 0
        state, step = restore_checkpoint(
            self.tcfg.ckpt_dir, self.state_sds, shardings=self.state_shard)
        self.events.append((step, "resume", f"restored step_{step}"))
        return state, step

    # ------------------------------------------------------------------
    def run(self):
        from repro.utils import compat

        compat.set_mesh(self.mesh)
        state, start = self.restore_or_init()
        times = []
        step = start
        with self.mesh:
            while step < self.tcfg.total_steps:
                batch = self.data.batch(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])  # blocks
                dt = time.perf_counter() - t0

                # --- straggler detection ---
                if len(times) >= 5:
                    med = statistics.median(times[-20:])
                    if dt > self.tcfg.straggler_factor * med:
                        self.events.append(
                            (step, "straggler",
                             f"{dt:.3f}s vs median {med:.3f}s"))
                times.append(dt)

                # --- NaN / spike guard with checkpoint rollback ---
                if not np.isfinite(loss):
                    self.events.append((step, "rollback", f"loss={loss}"))
                    self.ckpt.wait()
                    last = latest_step(self.tcfg.ckpt_dir)
                    if last is None:
                        state, step = self.init_state(), 0
                    else:
                        state, step = restore_checkpoint(
                            self.tcfg.ckpt_dir, self.state_sds,
                            shardings=self.state_shard)
                    continue

                step += 1
                if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps:
                    self.metrics_log.append(
                        {"step": step, "loss": loss,
                         "lr": float(metrics["lr"]),
                         "grad_norm": float(metrics["grad_norm"]),
                         "step_time_s": dt})
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(state, step)
        self.ckpt.wait()
        return state
