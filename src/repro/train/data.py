"""Sharded synthetic data pipeline.

Properties a real-cluster pipeline needs, built in:

  * deterministic & stateless-resumable: token (step, row, col) is a pure
    function of (seed, step, row) — restart at step k reproduces the exact
    stream, and the SAME data lands on whatever mesh is active (elastic
    rescale keeps the data order).
  * per-shard generation: `jax.make_array_from_callback` asks each device
    for its own index slice; no host materializes the global batch.
  * background prefetch: a depth-2 thread pipeline hides host generation
    behind device compute.

The synthetic stream is a Zipf-ish unigram mix with in-sequence structure
(short repeated motifs) so language models have signal to fit — losses
decrease meaningfully, which the e2e example and trainer tests rely on.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.launch.inputs import input_specs
from repro.sharding.partitioning import ACT_RULES, resolve_spec

__all__ = ["SyntheticLMData", "make_batch_arrays"]


def _row_tokens(seed: int, step: int, row: int, length: int, vocab: int):
    rng = np.random.Generator(np.random.Philox(
        key=[(seed << 32) + step, row]))
    # Zipf-ish unigram distribution over an active sub-vocab
    active = max(64, min(vocab, 4096))
    base = rng.zipf(1.3, size=length + 9) % active
    # repeated motif: every row embeds a periodic k-gram (learnable signal)
    motif = rng.integers(0, active, size=8)
    period = 16 + (row % 7)
    idx = np.arange(length + 9)
    base[idx % period < 8] = motif[(idx % period)[idx % period < 8]]
    return np.asarray(base[:length] % vocab, np.int32)


class SyntheticLMData:
    """Iterable over sharded train batches for one (cfg, shape)."""

    def __init__(self, cfg, shape_name: str, mesh, seed: int = 0,
                 prefetch: int = 2):
        self.cfg, self.mesh, self.seed = cfg, mesh, seed
        specs, axes = input_specs(cfg, shape_name)
        self.specs, self.axes = specs, axes
        self.shardings = {
            k: NamedSharding(mesh, resolve_spec(axes[k], specs[k].shape,
                                                mesh, ACT_RULES))
            for k in specs
        }
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._thread = None
        self._stop = threading.Event()

    def batch(self, step: int) -> dict:
        """Build the sharded global batch for `step` (pure function)."""
        cfg = self.cfg
        b, s = self.specs["tokens"].shape
        out = {}

        def tok_cb(shift):
            def cb(index):
                r0, r1, _ = index[0].indices(b)
                c0, c1, _ = index[1].indices(s)
                return np.stack([
                    _row_tokens(self.seed, step, r, s + 1, cfg.vocab)
                    [shift + c0: shift + c1] for r in range(r0, r1)])
            return cb

        for key, sds in self.specs.items():
            sh = self.shardings[key]
            if key == "tokens":
                out[key] = jax.make_array_from_callback(sds.shape, sh, tok_cb(0))
            elif key == "labels":
                out[key] = jax.make_array_from_callback(sds.shape, sh, tok_cb(1))
            elif key == "loss_mask":
                out[key] = jax.make_array_from_callback(
                    sds.shape, sh, lambda idx: np.ones(
                        tuple(sl.indices(dim)[1] - sl.indices(dim)[0]
                              for sl, dim in zip(idx, sds.shape)), np.float32))
            else:  # modality stubs: deterministic pseudo-embeddings
                def emb_cb(idx, sds=sds, key=key):
                    dims = tuple(sl.indices(dim)[1] - sl.indices(dim)[0]
                                 for sl, dim in zip(idx, sds.shape))
                    r = np.random.Generator(np.random.Philox(
                        key=[(self.seed << 32) + step,
                             hash(key) % (2**31)]))
                    return r.standard_normal(dims).astype(sds.dtype)
                out[key] = jax.make_array_from_callback(sds.shape, sh, emb_cb)
        return out

    # --- prefetch ------------------------------------------------------
    def start(self, first_step: int):
        def worker():
            step = first_step
            while not self._stop.is_set():
                try:
                    self._q.put(self.batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self):
        return self._q.get()

    def stop(self):
        self._stop.set()


def make_batch_arrays(cfg, shape_name, mesh, step=0, seed=0):
    """One-shot convenience (tests / examples)."""
    return SyntheticLMData(cfg, shape_name, mesh, seed).batch(step)
