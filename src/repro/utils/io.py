"""Small I/O helpers shared by the benchmark harness and the gateway.

``write_json_atomic`` exists so a CI lane that times out (or a crashing
benchmark) can never upload a truncated ``BENCH_*.json`` artifact: the
payload is serialized to a sibling temp file first and ``os.replace``d
into place, which is atomic on POSIX — readers see either the old file
or the complete new one, never a partial write.
"""

import json
import os


def write_json_atomic(path, obj, *, indent=2, default=float):
    """Serialize ``obj`` as JSON to ``path`` via write-temp-then-rename."""
    path = os.fspath(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=indent, default=default)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
