"""Version-compat shims over the jax mesh/sharding API surface.

The codebase is written against the modern ambient-mesh API
(``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh`` / ``jax.shard_map`` /
``jax.make_mesh(..., axis_types=...)``).  Older jaxlib pins (0.4.x — the CI
CPU image) predate parts of it; every call site goes through this module so
the fallback logic lives in exactly one place.

Fallback semantics on 0.4.x:

  * ``get_abstract_mesh()`` returns the ambient ``AbstractMesh`` when one is
    installed, else the physical mesh from the ``with mesh:`` thread-local
    context, else ``None``.  Callers treat ``None``/empty-shape as "no mesh".
  * ``set_mesh(mesh)`` installs ``mesh`` as the ambient mesh process-wide
    (enters both the abstract-mesh context and the legacy ``with mesh:``
    context and keeps them open — matching the modern global setter).
  * ``shard_map`` resolves an ``AbstractMesh`` argument to the physical mesh
    before delegating to ``jax.experimental.shard_map``.
  * ``make_mesh`` drops the ``axis_types`` kwarg when unsupported (axis types
    default to Auto there, which is what every caller passes).
"""

from __future__ import annotations

import jax

__all__ = ["get_abstract_mesh", "set_mesh", "shard_map", "make_mesh",
           "axis_size", "pcast"]

_NEW_API = hasattr(jax.sharding, "get_abstract_mesh") and hasattr(jax, "set_mesh")

# Contexts entered by the fallback set_mesh, kept open for process lifetime.
_HELD_CONTEXTS: list = []


def _thread_physical_mesh():
    from jax._src import mesh as _mesh_lib

    env = getattr(_mesh_lib, "thread_resources", None)
    if env is None:
        return None
    phys = env.env.physical_mesh
    return None if phys.empty else phys


def get_abstract_mesh():
    """Ambient mesh (AbstractMesh or Mesh) or None when none installed."""
    if _NEW_API:
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib

    if hasattr(_mesh_lib, "get_abstract_mesh"):
        am = _mesh_lib.get_abstract_mesh()
        # 0.4.x returns an empty tuple sentinel when nothing is installed
        if am is not None and getattr(am, "shape", None):
            return am
    return _thread_physical_mesh()


def set_mesh(mesh) -> None:
    """Install `mesh` as the ambient mesh (jax.set_mesh equivalent)."""
    if _NEW_API:
        jax.set_mesh(mesh)
        return
    from jax._src import mesh as _mesh_lib

    if hasattr(_mesh_lib, "set_abstract_mesh"):
        ctx = _mesh_lib.set_abstract_mesh(mesh.abstract_mesh)
        ctx.__enter__()
        _HELD_CONTEXTS.append(ctx)
    # Also enter the legacy thread-local mesh context so bare-PartitionSpec
    # with_sharding_constraint / shard_map resolve the physical mesh.
    mesh.__enter__()
    _HELD_CONTEXTS.append(mesh)


def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kw):
    """jax.shard_map, accepting an AbstractMesh on old jax too."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    if mesh is not None and not isinstance(mesh, jax.sharding.Mesh):
        phys = _thread_physical_mesh()
        if phys is None:
            raise ValueError(
                "shard_map over an AbstractMesh needs an installed physical "
                "mesh on this jax version (call compat.set_mesh first)")
        mesh = phys
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def _make_mesh_takes_axis_types() -> bool:
    import inspect

    return "axis_types" in inspect.signature(jax.make_mesh).parameters


_HAS_AXIS_TYPES = _make_mesh_takes_axis_types()


def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
    """jax.make_mesh with axis_types dropped when unsupported (0.4.x
    has no axis_types kwarg; axis types default to Auto there)."""
    if axis_types is not None and _HAS_AXIS_TYPES:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=axis_types, **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


def axis_size(axis) -> "jax.Array":
    """jax.lax.axis_size fallback: psum of 1 over the named axis."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    import jax.numpy as jnp

    return jax.lax.psum(jnp.int32(1), axis)


def pcast(x, axes, *, to):
    """jax.lax.pcast, a no-op on jax versions without varying-axis types."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x


def axis_type_auto(n: int):
    """(AxisType.Auto,) * n on jax versions that have axis types, else None."""
    at = getattr(jax.sharding, "AxisType", None)
    if at is None:
        return None
    return (at.Auto,) * n
