"""Post-SPMD HLO analyzer: loop-aware FLOP and collective-byte accounting.

XLA's ``compiled.cost_analysis()`` visits each ``while`` body ONCE (verified
empirically), so for scan-over-layers programs it undercounts FLOPs and
bytes by ~n_layers.  This module parses ``compiled.as_text()`` (the
optimized, partitioned HLO) and:

  1. splits it into computations,
  2. finds ``while`` instructions, recovers each loop's trip count from the
     integer constant in its condition computation,
  3. propagates execution multipliers through (possibly nested) loops,
  4. sums dot FLOPs (2 * prod(out) * contraction) and collective bytes
     (per-device shard shapes — post-partitioning HLO is per-device),
     weighted by the multipliers.

Ring-model byte factors: all-reduce counts 2x (reduce-scatter+all-gather
phase), everything else 1x of max(in, out) bytes.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:,\s*(?:condition=%([\w\.\-]+)|body=%([\w\.\-]+))){2}")
_CONST_RE = re.compile(r"=\s*[su]32\[\]\s*constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> int:
    """Total bytes of every dtype[dims] group in `text` (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _split_computations(txt: str) -> dict:
    comps, cur = {}, None
    for line in txt.splitlines():
        ls = line.rstrip()
        s = ls.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            name = s.split()[1] if s.startswith("ENTRY") else s.split()[0]
            cur = name.lstrip("%").split(" ")[0].split("(")[0]
            comps[cur] = []
        elif s == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(s)
    return comps


def analyze_hlo(txt: str, default_trip: int = 1) -> dict:
    comps = _split_computations(txt)
    entry = None
    for line in txt.splitlines():
        if line.startswith("ENTRY"):
            entry = line.split()[1].lstrip("%").split("(")[0]
    if entry is None and comps:
        entry = next(iter(comps))

    # strip metadata before any numeric parsing
    def clean(s):
        return re.sub(r",?\s*metadata=\{.*?\}", "", s)

    # per-computation: defined shapes, whiles, dots, collectives
    info = {}
    for name, lines in comps.items():
        shapes, whiles, dots, colls = {}, [], [], []
        for raw in lines:
            s = clean(raw)
            m = _DEF_RE.match(s)
            if not m:
                continue
            iname, rhs = m.groups()
            shapes[iname] = rhs.split(" ", 1)[0] if rhs else ""
            # record the full result-shape prefix (up to the op name)
            if " while(" in s:
                cond = re.search(r"condition=%([\w\.\-]+)", s)
                body = re.search(r"body=%([\w\.\-]+)", s)
                if cond and body:
                    whiles.append((cond.group(1), body.group(1)))
            elif " dot(" in s:
                dots.append((iname, s))
            else:
                for c in COLLECTIVES:
                    if f" {c}(" in s or f" {c}-start(" in s:
                        colls.append((c, iname, s))
                        break
        info[name] = dict(shapes=shapes, whiles=whiles, dots=dots, colls=colls)

    # trip count per condition computation
    def trip_of(cond_name: str) -> int:
        best = default_trip
        for raw in comps.get(cond_name, ()):
            for m in _CONST_RE.finditer(clean(raw)):
                best = max(best, int(m.group(1)))
        return best

    # propagate multipliers (fixpoint over nesting depth)
    mult = defaultdict(float)
    mult[entry] = 1.0
    for _ in range(12):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for name, m in list(mult.items()):
            for cond, body in info.get(name, {}).get("whiles", ()):
                new[body] += m * trip_of(cond)
        for k, v in new.items():
            if abs(mult.get(k, 0) - v) > 1e-9 and k != entry:
                changed = True
        prev_bodies = {k: v for k, v in new.items()}
        for k, v in prev_bodies.items():
            mult[k] = v
        if not changed:
            break

    # --- weighted sums ---
    flops = 0.0
    dot_bytes = 0.0
    coll = defaultdict(lambda: {"count": 0.0, "bytes": 0.0})
    for name, meta in info.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        shapes = meta["shapes"]
        for iname, s in meta["dots"]:
            _, out_dims = _shape_dims(s.split("=", 1)[1])
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            cm = _CONTRACT_RE.search(s)
            args = s.split(" dot(", 1)[1].split(")", 1)[0]
            ops = re.findall(r"%([\w\.\-]+)", args)
            contract = 1
            if cm and ops:
                _, lhs_dims = _shape_dims(shapes.get(ops[0], ""))
                for di in cm.group(1).split(","):
                    if di and int(di) < len(lhs_dims):
                        contract *= lhs_dims[int(di)]
            flops += m * 2.0 * out_elems * contract
            # operand + result traffic
            rhs_shape = shapes.get(ops[1], "") if len(ops) > 1 else ""
            lhs_shape = shapes.get(ops[0], "") if ops else ""
            dot_bytes += m * (_shape_bytes(s.split("=", 1)[1].split(" dot(")[0])
                              + _shape_bytes(lhs_shape) + _shape_bytes(rhs_shape))
        for ctype, iname, s in meta["colls"]:
            res = s.split("=", 1)[1]
            res_prefix = res.split(f" {ctype}")[0]
            out_b = _shape_bytes(res_prefix)
            args_seg = s.split(f" {ctype}(", 1)[-1].split(")", 1)[0]
            ops = re.findall(r"%([\w\.\-]+)", args_seg)
            in_b = sum(_shape_bytes(shapes.get(o, "")) for o in ops)
            moved = max(out_b, in_b) * (2.0 if ctype == "all-reduce" else 1.0)
            # CPU lowering promotes bf16 collectives to f32 (identified by a
            # convert fusion feeding the collective); count logical bytes.
            if any(o.startswith("convert") for o in ops) and "f32[" in s:
                moved *= 0.5
            coll[ctype]["count"] += m
            coll[ctype]["bytes"] += m * moved

    total_coll = sum(v["bytes"] for v in coll.values())
    return {
        "entry": entry,
        "flops": flops,  # loop-weighted dot FLOPs (per device)
        "dot_bytes": dot_bytes,  # loop-weighted dot operand/result bytes
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_bytes": total_coll,  # per-device bytes moved
        "loop_multipliers": {k: v for k, v in mult.items() if v > 1},
    }
