"""Shared utilities (HLO analysis, tree helpers, atomic JSON writes)."""

from repro.utils.io import write_json_atomic

__all__ = ["write_json_atomic"]
