"""Shared utilities (HLO analysis, tree helpers)."""
