"""Elastic scaling: restore any checkpoint onto any mesh.

Checkpoints store full host arrays (train/checkpoint.py), so rescaling is:
build the new mesh, re-derive shardings from the SAME logical axes, and
device_put.  `rescale_state` is the one-call path the trainer uses when
the scheduler grows/shrinks the slice; `verify_rescale` round-trips a
state through a different mesh and asserts bit-identity (used in tests).
"""

from __future__ import annotations

import jax
import numpy as np

from repro.launch.steps import state_specs
from repro.train.checkpoint import restore_checkpoint

__all__ = ["rescale_state", "verify_rescale"]


def rescale_state(ckpt_dir: str, md, cfg, new_mesh, step=None):
    """Load the latest checkpoint and shard it for `new_mesh`."""
    sds, shard = state_specs(md, cfg, new_mesh)
    return restore_checkpoint(ckpt_dir, sds, step=step, shardings=shard)


def verify_rescale(state_a, state_b) -> bool:
    """Bit-identity of two (differently sharded) states."""
    flat_a = jax.tree.leaves(state_a)
    flat_b = jax.tree.leaves(state_b)
    return all(
        np.array_equal(np.asarray(jax.device_get(a)),
                       np.asarray(jax.device_get(b)))
        for a, b in zip(flat_a, flat_b))
