"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the right step
function (train_step / prefill_step / serve_step per shape kind) with
explicit in/out shardings, compiles it, prints memory_analysis() and
cost_analysis(), and extracts loop-aware roofline terms from the optimized
HLO into results/dryrun/*.json (consumed by benchmarks/roofline.py and
EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
      --shape train_4k [--multi-pod] [--attn-mode camformer] [--all]
"""

# The placeholder-device flag MUST precede any jax import (device count is
# locked at first backend init).  Do NOT set this anywhere global.
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config  # noqa: E402
from repro.launch import analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (batch_specs, cache_specs_trees,  # noqa: E402
                                make_prefill_step, make_serve_step,
                                make_train_step, state_specs)
from repro.models import get_model_def  # noqa: E402
from repro.models.module import count_params  # noqa: E402
from repro.sharding.partitioning import ACT_RULES, resolve_spec  # noqa: E402
from repro.utils.hlo import analyze_hlo  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")

# Full-attention archs skip *dense* long_500k (quadratic-prefill families,
# per the assignment) but run it with the paper's technique: binary packed-K
# cache + top-32 sparse V gather makes 500k-decode tractable (DESIGN.md §4).
ATTENTION_FAMILIES = ("dense", "moe", "vlm", "audio")


def effective_config(arch: str, shape: str, backend: str | None,
                     dist_topk: bool = False, prefill_chunk: int = 0):
    cfg = get_config(arch)
    if dist_topk:
        cfg = cfg.replace(distributed_topk=True)
    if prefill_chunk:
        cfg = cfg.replace(prefill_chunk=prefill_chunk)
    note = ""
    if backend:
        cfg = cfg.replace(attn_backend=backend)
        note = f"backend={backend} (CLI)"
    elif shape == "long_500k" and cfg.family in ATTENTION_FAMILIES:
        cfg = cfg.replace(attn_backend="camformer")
        note = ("dense long_500k skipped (full attention); run with "
                "CAMformer binary top-k cache per paper Sec. IV-C")
    return cfg, note


def build_cell(arch: str, shape: str, mesh, backend: str | None,
               dist_topk: bool = False, prefill_chunk: int = 0):
    cfg, note = effective_config(arch, shape, backend, dist_topk,
                                 prefill_chunk)
    md = get_model_def(cfg)
    kind = SHAPES[shape]["kind"]
    sh = SHAPES[shape]
    n_params = count_params(md.specs(cfg))

    if kind == "train":
        from repro.launch.steps import METRIC_KEYS

        step, _ = make_train_step(md, cfg)
        state_sds, state_shard = state_specs(md, cfg, mesh)
        b_sds, b_shard = batch_specs(cfg, shape, mesh)
        metrics_shard = {k: NamedSharding(mesh, P()) for k in METRIC_KEYS}
        fn = jax.jit(step, in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, metrics_shard),
                     donate_argnums=(0,))
        args = (state_sds, b_sds)
    elif kind == "prefill":
        from repro.launch.steps import params_specs

        step = make_prefill_step(md, cfg)
        p_sds, p_serve_shard = params_specs(md, cfg, mesh, serve=True)
        p_shard = {"params": p_serve_shard}
        b_sds, b_shard = batch_specs(cfg, shape, mesh)
        c_sds, c_shard = cache_specs_trees(md, cfg, sh["global_batch"],
                                           sh["seq_len"], mesh)
        logits_shard = NamedSharding(mesh, resolve_spec(
            ("batch", "vocab"), (sh["global_batch"], cfg.vocab), mesh,
            ACT_RULES))
        fn = jax.jit(step,
                     in_shardings=(p_shard["params"], b_shard, c_shard),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(2,))
        args = (p_sds, b_sds, c_sds)
    else:  # decode
        from repro.launch.steps import params_specs

        step = make_serve_step(md, cfg)
        p_sds_only, p_serve_shard = params_specs(md, cfg, mesh, serve=True)
        p_sds = {"params": p_sds_only}
        p_shard_all = {"params": p_serve_shard}
        b_sds, b_shard = batch_specs(cfg, shape, mesh, serve=True)
        c_sds, c_shard = cache_specs_trees(md, cfg, sh["global_batch"],
                                           sh["seq_len"], mesh)
        logits_shard = NamedSharding(mesh, resolve_spec(
            ("batch", "vocab"), (sh["global_batch"], cfg.vocab), mesh,
            ACT_RULES))
        fn = jax.jit(step,
                     in_shardings=(p_shard_all["params"], b_shard["tokens"],
                                   b_shard["pos"], b_shard["kv_len"], c_shard),
                     out_shardings=(logits_shard, c_shard),
                     donate_argnums=(4,))
        args = (p_sds["params"], b_sds["tokens"], b_sds["pos"],
                b_sds["kv_len"], c_sds)
    return cfg, md, fn, args, n_params, note


def run_cell(arch: str, shape: str, *, multi_pod: bool, backend=None,
             out_dir=RESULTS_DIR, tag="", dist_topk=False, prefill_chunk=0):
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    cfg, md, fn, args, n_params, note = build_cell(arch, shape, mesh,
                                                   backend, dist_topk,
                                                   prefill_chunk)
    from repro.utils import compat

    compat.set_mesh(mesh)  # installs the ambient mesh for constrain()
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    print(f"[{arch} x {shape} x {'multipod' if multi_pod else 'pod'}] "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    print("  memory_analysis:", mem)
    print("  cost_analysis: flops=%.3e bytes=%.3e"
          % (cost.get("flops", 0), cost.get("bytes accessed", 0)))
    hlo = analyze_hlo(compiled.as_text())
    roof = analysis.roofline_terms(hlo, cfg, shape, n_params, chips)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape, "kind": SHAPES[shape]["kind"],
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "backend": cfg.backend,
        "note": note, "tag": tag,
        "profile": __import__("repro.sharding.partitioning",
                              fromlist=["x"]).get_parallelism_profile(),
        "n_params": n_params,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
        },
        "xla_cost_analysis": {
            "flops_unrolled": cost.get("flops", 0.0),
            "bytes_accessed_unrolled": cost.get("bytes accessed", 0.0),
        },
        "hlo": {
            "flops_per_device": hlo["flops"],
            "dot_bytes_per_device": hlo["dot_bytes"],
            "collective_bytes_per_device": hlo["collective_bytes"],
            "collectives": hlo["collectives"],
            "loop_multipliers": {k: v for k, v in
                                 sorted(hlo["loop_multipliers"].items())[:12]},
        },
        "roofline": roof,
    }
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}_{shape}_{'multipod' if multi_pod else 'pod'}"
    name += f"_{tag}" if tag else ""
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=float)
    print(f"  roofline: compute {roof['compute_s']:.3e}s | memory "
          f"{roof['memory_s']:.3e}s | collective {roof['collective_s']:.3e}s "
          f"-> {roof['dominant']}-bound, roofline fraction "
          f"{roof['roofline_fraction']:.3f}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    from repro.launch.cli import add_backend_args, resolve_backend_arg
    add_backend_args(ap, choices=[None, "dense", "binary", "camformer"],
                     layer_policy=False)  # scan-compiled cells are uniform
    ap.add_argument("--tag", default="")
    ap.add_argument("--profile", default="tp", choices=["tp", "dp"],
                    help="sharding profile (see sharding/partitioning.py)")
    ap.add_argument("--dist-topk", action="store_true",
                    help="distributed two-stage CAM search (shard_map)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill (tokens per chunk; 0 = whole-seq)")
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape) cell")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    from repro.sharding.partitioning import set_parallelism_profile
    set_parallelism_profile(args.profile)

    backend = resolve_backend_arg(args)
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                try:
                    run_cell(arch, shape, multi_pod=args.multi_pod,
                             backend=backend, out_dir=args.out_dir,
                             tag=args.tag)
                except Exception as e:  # noqa: BLE001 — report and continue
                    print(f"[{arch} x {shape}] FAILED: {type(e).__name__}: {e}")
        return
    run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
             backend=backend, out_dir=args.out_dir, tag=args.tag,
             dist_topk=args.dist_topk, prefill_chunk=args.prefill_chunk)


if __name__ == "__main__":
    main()
