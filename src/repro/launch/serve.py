"""Serving driver: overlapped continuous batching with streamed outputs.

    PYTHONPATH=src python -m repro.launch.serve --arch camformer-bert --smoke \
        --requests 12 --max-new 24 [--backend camformer] \
        [--layer-backends dense,camformer] [--mode overlap|sync] \
        [--prefill-slice 64] [--temperature 0.8 --top-k 40 --top-p 0.95] \
        [--shared-prefix 32] [--no-stream]

Tokens print as they are generated (``engine.stream()``).  ``--mode
overlap`` (default) runs the dispatch-ahead loop — tick t+1 is enqueued
before tick t's tokens are read, so host scheduling overlaps the device
forward; ``--mode sync`` reads every tick (token-for-token identical).
``--prefill-slice N`` prefills joining prompts in N-token chunks across
ticks while resident slots keep decoding (continuous chunked-prefill
batching).  ``--shared-prefix N`` prepends a common N-token system prompt
to every request to exercise the copy-on-write prefix sharing (the
page-pool report shows the aliasing; the prefix stays LRU-retained after
the pool drains).
"""

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.launch.cli import add_backend_args, apply_backend_args
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine, parse_faults


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    add_backend_args(ap)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="common system-prompt length prepended to every "
                         "request (exercises COW prefix sharing)")
    ap.add_argument("--page-size", type=int, default=64,
                    help="paged-cache page size (camformer mode)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="page-pool size; default = full residency")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill chunk length (0 = whole prompt)")
    ap.add_argument("--mode", default="overlap", choices=("overlap", "sync"),
                    help="engine loop: dispatch-ahead overlap (default) or "
                         "read-every-tick sync")
    ap.add_argument("--prefill-slice", type=int, default=None,
                    help="continuous batching: prefill joining prompts in "
                         "chunks of this many tokens across ticks "
                         "(default: whole prompt in the admission tick)")
    ap.add_argument("--paged-impl", default=None,
                    choices=("fused", "gather"),
                    help="paged decode realization: fused Pallas "
                         "flash/CAM kernels (default) or the XLA "
                         "page-gather reference")
    ap.add_argument("--prefill-impl", default=None,
                    choices=("auto", "fused", "gather"),
                    help="Sq>1 chunk realization (chunked prefill and "
                         "speculative verify): fused paged flash kernel "
                         "or the XLA page-gather reference; 'auto' "
                         "(default) follows --paged-impl")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="self-speculative decoding: draft this many "
                         "tokens per tick with the binary stack and "
                         "verify k+1 positions in one fused target step "
                         "(0 = off, token-for-token plain decode)")
    ap.add_argument("--spec-backend", default=None,
                    help="drafter attention backend (default 'binary')")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: head-shard every page "
                         "pool over a tp-axis device mesh "
                         "(launch/mesh.py make_tp_mesh) and run the "
                         "fused tick shard_map-wide; 1 (default) is the "
                         "single-device engine, same code path, and any "
                         "degree is token-for-token identical to it")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission: submissions beyond this "
                         "queue depth raise QueueFullError (default: "
                         "unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: requests not finished "
                         "within this many ms of submit end with "
                         "finish_reason='timeout'")
    ap.add_argument("--faults", default=None,
                    help="chaos fault plan, e.g. 'step.error@3,"
                         "kv.exhaust@1:4,tick.delay@0:20:p0.5:d0.01' "
                         "(serving/faults.py grammar; default: none)")
    ap.add_argument("--no-stream", action="store_true",
                    help="suppress per-token output, print only summaries")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = apply_backend_args(cfg, args)
    if args.prefill_chunk is not None:
        cfg = cfg.replace(prefill_chunk=args.prefill_chunk)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    faults = parse_faults(args.faults) if args.faults else None
    eng = ServeEngine(md, cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len, page_size=args.page_size,
                      n_pages=args.n_pages, mode=args.mode,
                      prefill_slice=args.prefill_slice,
                      paged_impl=args.paged_impl,
                      prefill_impl=args.prefill_impl,
                      spec_k=args.spec_k, spec_backend=args.spec_backend,
                      tp=args.tp, max_queue=args.max_queue, faults=faults)
    layout = cfg.uniform_backend or ",".join(cfg.layer_backends)
    shard = (f", head-sharded tp={eng.tp} over {jax.device_count()} devices"
             if eng.tp > 1 else "")
    print(f"paged KV cache [{layout}]: {eng.kv.n_pages} pages x "
          f"{eng.kv.page_size} tokens "
          f"(page table {eng.kv.table.shape}{shard})")
    sampling = SamplingParams(
        temperature=args.temperature, top_k=args.top_k, top_p=args.top_p,
        max_new=args.max_new, deadline_ms=args.deadline_ms)
    rng = jax.random.PRNGKey(7)
    shared = list(range(1, args.shared_prefix + 1))
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        plen = 4 + int(jax.random.randint(sub, (), 0, 12))
        prompt = shared + list(
            map(int, jax.random.randint(sub, (plen,), 0, cfg.vocab)))
        eng.submit(Request(prompt=prompt, sampling=sampling, rid=i))
    import time as _time
    t0 = _time.perf_counter()
    for out in eng.stream():
        if not args.no_stream:
            tail = f"  [{out.finish_reason}]" if out.finished else ""
            print(f"  req {out.rid} #{out.index}: {out.token}{tail}")
    wall = _time.perf_counter() - t0
    print(f"[{args.mode}] {eng.ticks} decode ticks in {wall:.2f}s "
          f"({eng.ticks / max(wall, 1e-9):.1f} ticks/s), "
          f"{eng.readbacks} readbacks, host idle "
          f"{eng.blocked_s / max(wall, 1e-9):.0%}")
    if eng.tick_errors:
        print(f"chaos: {eng.tick_errors} tick errors contained "
              f"(last: {eng.last_error}), "
              f"{eng.sched.timeouts} timeouts, "
              f"{eng.sched.rejections} rejections")
    if eng.spec_k:
        print(f"speculation: k={eng.spec_k}, "
              f"{eng.spec_accepted}/{eng.spec_proposed} drafts accepted "
              f"({eng.spec_acceptance:.0%})")
    print(f"peak pool residency: {eng.peak_pages}/{eng.kv.n_pages - 1} pages"
          f" ({eng.kv.shared_pages} still shared, "
          f"{eng.kv.retained_pages} prefix pages retained at drain)")
    for r in sorted(eng.done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] "
              f"prefix_hit={r.prefix_matched} -> {r.tokens}")


if __name__ == "__main__":
    main()
