"""Serving driver: batched decode with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch camformer-bert --smoke \
        --requests 12 --max-new 24 [--attn-mode camformer]
"""

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attn-mode", default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.attn_mode:
        cfg = cfg.replace(attn_mode=args.attn_mode)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len)
    rng = jax.random.PRNGKey(7)
    for i in range(args.requests):
        rng, sub = jax.random.split(rng)
        plen = 4 + int(jax.random.randint(sub, (), 0, 12))
        prompt = list(map(int, jax.random.randint(sub, (plen,), 0, cfg.vocab)))
        eng.submit(Request(prompt=prompt, max_new_tokens=args.max_new, rid=i))
    done = eng.run()
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.tokens}")


if __name__ == "__main__":
    main()
