"""Shared CLI plumbing for the launch drivers.

Backend selection is one flag set across serve/train/dryrun: ``--backend``
(a core/backend.py registry name) plus ``--layer-backends`` for the
per-layer policy; ``--attn-mode`` is kept as a deprecated alias that maps
onto ``--backend`` with a note.
"""

from __future__ import annotations

import argparse
import warnings

__all__ = ["add_backend_args", "apply_backend_args", "resolve_backend_arg"]


def add_backend_args(ap: argparse.ArgumentParser, *, choices=None,
                     layer_policy: bool = True):
    ap.add_argument("--backend", default=None, choices=choices,
                    help="attention backend (core/backend.py registry: "
                         "dense | binary | camformer)")
    ap.add_argument("--attn-mode", default=None, choices=choices,
                    help="DEPRECATED: old spelling of --backend")
    if layer_policy:
        ap.add_argument("--layer-backends", default=None,
                        help="comma-separated per-layer backend policy, "
                             "cycled over the stack (e.g. dense,camformer)")


def resolve_backend_arg(args) -> str | None:
    """The requested backend name, honoring the deprecated alias."""
    if args.attn_mode:
        if args.backend and args.backend != args.attn_mode:
            raise SystemExit(
                f"conflicting --attn-mode {args.attn_mode} (deprecated "
                f"alias) and --backend {args.backend}; pass only --backend")
        warnings.warn(
            f"--attn-mode is deprecated; use --backend {args.attn_mode}",
            DeprecationWarning, stacklevel=2)
        # DeprecationWarning is filtered outside __main__ by default;
        # CLI users still need to see the note
        print(f"note: --attn-mode is deprecated; use --backend "
              f"{args.attn_mode}")
        return args.attn_mode
    return args.backend


def apply_backend_args(cfg, args):
    backend = resolve_backend_arg(args)
    if backend:
        cfg = cfg.replace(attn_backend=backend)
    if getattr(args, "layer_backends", None):
        cfg = cfg.replace(
            layer_backends=tuple(args.layer_backends.split(",")))
    return cfg
