"""Shared CLI plumbing for the launch drivers.

Backend selection is one flag set across serve/train/dryrun: ``--backend``
(a core/backend.py registry name) plus ``--layer-backends`` for the
per-layer policy.  ``--attn-mode`` (deprecated in PR 2-3) is REMOVED; the
flag is still parsed (hidden) purely so stale scripts fail with a clear
migration error instead of argparse's generic unrecognized-argument one.
"""

from __future__ import annotations

import argparse

__all__ = ["add_backend_args", "apply_backend_args", "resolve_backend_arg"]


def add_backend_args(ap: argparse.ArgumentParser, *, choices=None,
                     layer_policy: bool = True):
    ap.add_argument("--backend", default=None, choices=choices,
                    help="attention backend (core/backend.py registry: "
                         "dense | binary | camformer | hybrid)")
    ap.add_argument("--attn-mode", default=None, help=argparse.SUPPRESS)
    if layer_policy:
        ap.add_argument("--layer-backends", default=None,
                        help="comma-separated per-layer backend policy, "
                             "cycled over the stack (e.g. dense,camformer)")


def resolve_backend_arg(args) -> str | None:
    """The requested backend name; stale --attn-mode usage is a clean
    error pointing at the migration."""
    if getattr(args, "attn_mode", None):
        raise SystemExit(
            f"--attn-mode was removed; use --backend {args.attn_mode} "
            "(or --layer-backends for a per-layer policy)")
    return args.backend


def apply_backend_args(cfg, args):
    backend = resolve_backend_arg(args)
    if backend:
        cfg = cfg.replace(attn_backend=backend)
    if getattr(args, "layer_backends", None):
        cfg = cfg.replace(
            layer_backends=tuple(args.layer_backends.split(",")))
    return cfg
