"""Gateway launcher: serve a model over HTTP/SSE.

    PYTHONPATH=src python -m repro.launch.gateway --arch camformer-bert \\
        --smoke [--backend camformer] [--host 127.0.0.1 --port 8000] \\
        [--max-batch 8 --max-len 256] [--mode overlap|sync] \\
        [--prefill-slice 64] [--paged-impl fused|gather]

Then point traffic at it:

    curl -N -X POST http://127.0.0.1:8000/v1/generate \\
        -d '{"prompt": [3, 5, 8, 1], "max_new": 16, "temperature": 0.8}'
    curl http://127.0.0.1:8000/healthz
    curl http://127.0.0.1:8000/metrics

Each generated token streams back as a server-sent event; closing the
connection mid-stream cancels the request and frees its pages.  See
``benchmarks/serve_slo.py`` for the Poisson load generator that drives
this endpoint (or the engine in-process) and reports TTFT/TPOT
percentiles and goodput-under-SLO.
"""

import argparse
import asyncio

import jax

from repro.configs import get_config, smoke_config
from repro.launch.cli import add_backend_args, apply_backend_args
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import ServeEngine, parse_faults
from repro.serving.gateway import Gateway


def build_engine(args) -> ServeEngine:
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = apply_backend_args(cfg, args)
    if args.prefill_chunk is not None:
        cfg = cfg.replace(prefill_chunk=args.prefill_chunk)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    faults = parse_faults(args.faults) if getattr(args, "faults", None) else None
    return ServeEngine(
        md,
        cfg,
        params,
        max_batch=args.max_batch,
        max_len=args.max_len,
        page_size=args.page_size,
        n_pages=args.n_pages,
        mode=args.mode,
        prefill_slice=args.prefill_slice,
        paged_impl=args.paged_impl,
        prefill_impl=args.prefill_impl,
        spec_k=args.spec_k,
        spec_backend=args.spec_backend,
        tp=args.tp,
        max_queue=getattr(args, "max_queue", None),
        faults=faults,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    add_backend_args(ap)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000, help="0 = pick a free port")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--mode", default="overlap", choices=("overlap", "sync"))
    ap.add_argument(
        "--prefill-slice",
        type=int,
        default=None,
        help="continuous batching: prefill joining prompts in chunks of "
        "this many tokens across ticks",
    )
    ap.add_argument("--paged-impl", default=None, choices=("fused", "gather"))
    ap.add_argument(
        "--prefill-impl",
        default=None,
        choices=("auto", "fused", "gather"),
        help="Sq>1 chunk realization (chunked prefill / speculative "
        "verify): 'auto' follows --paged-impl",
    )
    ap.add_argument(
        "--spec-k",
        type=int,
        default=None,
        help="self-speculative decoding: binary-stack drafts per tick, "
        "verified k+1 at a time in one fused target step (0 = off)",
    )
    ap.add_argument(
        "--spec-backend",
        default=None,
        help="drafter attention backend (default 'binary')",
    )
    ap.add_argument(
        "--max-queue",
        type=int,
        default=None,
        help="bounded admission: beyond this queue depth new requests get "
        "HTTP 429 + Retry-After (default: unbounded)",
    )
    ap.add_argument(
        "--faults",
        default=None,
        help="chaos fault plan, e.g. 'step.error@3,kv.exhaust@1:4' "
        "(serving/faults.py grammar; default: none)",
    )
    ap.add_argument(
        "--tp",
        type=int,
        default=1,
        help="tensor-parallel degree: head-shard the page pools over a "
        "tp-axis device mesh (launch/mesh.py make_tp_mesh); 1 = the "
        "single-device engine, same code path",
    )
    args = ap.parse_args()

    engine = build_engine(args)
    layout = engine.cfg.uniform_backend or ",".join(engine.cfg.layer_backends)

    async def serve() -> None:
        gw = Gateway(engine, host=args.host, port=args.port)
        await gw.start()
        shard = f", head-sharded tp={engine.tp}" if engine.tp > 1 else ""
        print(
            f"gateway [{layout}] listening on http://{args.host}:{gw.port} "
            f"(pool {engine.kv.n_pages - 1} pages x {engine.kv.page_size} "
            f"tokens, {args.mode} loop{shard})"
        )
        try:
            await gw.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await gw.aclose()

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        print("gateway stopped")


if __name__ == "__main__":
    main()
