"""Step functions (train / prefill / serve) + sharding assembly.

Everything the dry-run, trainer, and server share: jit-able step closures
over a ModelDef, and the (ShapeDtypeStruct, NamedSharding) trees for every
argument, derived from logical axes via sharding/partitioning.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.inputs import input_specs, serve_input_specs
from repro.models.module import param_shapes, tree_axes
from repro.models.transformer import dtype_of
from repro.sharding.partitioning import (ACT_RULES, CACHE_RULES, PARAM_RULES,
                                         tree_pspecs)
from repro.train.optimizer import adamw, cosine_schedule

__all__ = [
    "cast_params", "make_train_step", "make_prefill_step", "make_serve_step",
    "state_specs", "batch_specs", "cache_specs_trees", "named",
]


def cast_params(params, dtype):
    """Compute-precision copy (cast the sharded fp32 masters once per step,
    BEFORE consumption, so GSPMD gathers bf16 — halves FSDP traffic)."""
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params)


def named(mesh, tree_of_pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------- step factories ----------------

def make_train_step(md, cfg, *, peak_lr=3e-4, warmup=2000, total_steps=100_000,
                    accum: int = 1):
    """Returns (train_step, optimizer).  state = {params, opt}."""
    opt = adamw(cosine_schedule(peak_lr, warmup, total_steps))
    dt = dtype_of(cfg)

    def loss_fn(params, batch):
        return md.loss(cast_params(params, dt), batch, cfg)

    from repro.models.module import tree_axes
    from repro.sharding.partitioning import constrain as _constrain

    grad_axes = tree_axes(md.specs(cfg))

    def _shard_grads(grads):
        # Constrain gradients to the parameter sharding at the autodiff
        # boundary so the partitioner emits reduce-scatter (not all-reduce
        # + slice) for the FSDP gradient sync.
        return jax.tree.map(
            lambda g, ax: _constrain(g, ax, PARAM_RULES), grads, grad_axes)

    def train_step(state, batch):
        if accum == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch)
            grads = _shard_grads(grads)
        else:  # microbatched gradient accumulation
            def micro(carry, mb):
                gsum, lsum = carry
                (mb_loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], mb)
                return (jax.tree.map(jnp.add, gsum, g), lsum + mb_loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state["params"])
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss, aux = loss / accum, {}
        new_params, opt_state, ostats = opt.update(grads, state["opt"],
                                                   state["params"])
        raw = {"loss": loss, **ostats, **aux}
        metrics = {k: jnp.asarray(raw.get(k, 0.0), jnp.float32)
                   for k in METRIC_KEYS}
        return {"params": new_params, "opt": opt_state}, metrics

    return train_step, opt


METRIC_KEYS = ("loss", "grad_norm", "lr", "ce", "tokens",
               "moe_aux_loss", "moe_drop_frac")


def make_prefill_step(md, cfg):
    dt = dtype_of(cfg)

    def prefill_step(params, batch, caches):
        return md.prefill(cast_params(params, dt), batch, caches, cfg)

    return prefill_step


def make_serve_step(md, cfg):
    dt = dtype_of(cfg)

    def serve_step(params, tokens, pos, kv_len, caches):
        return md.decode(cast_params(params, dt), tokens, pos, kv_len,
                         caches, cfg)

    return serve_step


# ---------------- sharding assembly ----------------

def params_specs(md, cfg, mesh, *, serve: bool = False):
    from repro.sharding.partitioning import SERVE_PARAM_RULES

    specs = md.specs(cfg)
    # serving loads bf16 weights (the standard deployment format); training
    # holds fp32 masters and casts a bf16 compute copy per step.
    shapes = param_shapes(specs, dtype_of(cfg) if serve else jnp.float32)
    rules = SERVE_PARAM_RULES if serve else PARAM_RULES
    pspecs = tree_pspecs(tree_axes(specs), shapes, mesh, rules)
    return shapes, named(mesh, pspecs)


def state_specs(md, cfg, mesh):
    """(SDS tree, sharding tree) for the full train state."""
    shapes, pshard = params_specs(md, cfg, mesh)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    sds = {"params": shapes,
           "opt": {"m": shapes, "v": shapes, "step": scalar}}
    shard = {"params": pshard,
             "opt": {"m": pshard, "v": pshard,
                     "step": NamedSharding(mesh, P())}}
    return sds, shard


def batch_specs(cfg, shape_name, mesh, *, serve=False):
    specs, axes = (serve_input_specs if serve else input_specs)(cfg, shape_name)
    from repro.sharding.partitioning import resolve_spec

    shard = {k: NamedSharding(mesh, resolve_spec(axes[k], specs[k].shape, mesh,
                                                 ACT_RULES))
             for k in specs}
    return specs, shard


_IS_CACHE_LEAF = lambda x: (isinstance(x, tuple) and len(x) == 2
                            and isinstance(x[0], jax.ShapeDtypeStruct))


def cache_specs_trees(md, cfg, batch: int, cache_len: int, mesh):
    tree = md.cache_specs(cfg, batch, cache_len)
    sds = jax.tree.map(lambda t: t[0], tree, is_leaf=_IS_CACHE_LEAF)
    axes = jax.tree.map(lambda t: t[1], tree, is_leaf=_IS_CACHE_LEAF)
    from repro.sharding.partitioning import resolve_spec

    shard = jax.tree.map(
        lambda t: NamedSharding(mesh, resolve_spec(t[1], t[0].shape, mesh,
                                                   CACHE_RULES)),
        tree, is_leaf=_IS_CACHE_LEAF)
    return sds, shard
