"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

`input_specs()` returns (tree of ShapeDtypeStruct, tree of logical axes) for
the given workload kind — weak-type-correct, shardable, no device
allocation.  Modality frontends are stubs per the assignment: audio cells
get precomputed frame embeddings, VLM cells get patch embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES

__all__ = ["input_specs", "serve_input_specs", "batch_axes"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape_name: str):
    """Train/prefill batch specs. Returns (specs, axes) trees (dicts)."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    specs = {}
    axes = {}
    s_text = s
    if cfg.family == "vlm":
        s_text = s - cfg.n_patches  # patches + text fill the assigned seq
        specs["image_embeds"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        axes["image_embeds"] = ("batch", None, "embed")
    if cfg.family == "audio":
        specs["audio_features"] = _sds((b, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        axes["audio_features"] = ("batch", None, "embed")
    specs["tokens"] = _sds((b, s_text), jnp.int32)
    axes["tokens"] = ("batch", "seq")
    if sh["kind"] == "train":
        specs["labels"] = _sds((b, s_text), jnp.int32)
        axes["labels"] = ("batch", "seq")
        specs["loss_mask"] = _sds((b, s_text), jnp.float32)
        axes["loss_mask"] = ("batch", "seq")
    return specs, axes


def serve_input_specs(cfg, shape_name: str):
    """Decode-step inputs: one new token against a seq_len cache."""
    sh = SHAPES[shape_name]
    b = sh["global_batch"]
    specs = {
        "tokens": _sds((b,), jnp.int32),
        "pos": _sds((b,), jnp.int32),
        "kv_len": _sds((b,), jnp.int32),
    }
    axes = {"tokens": ("batch",), "pos": ("batch",), "kv_len": ("batch",)}
    return specs, axes


def batch_axes(cfg, shape_name: str):
    return input_specs(cfg, shape_name)[1]
