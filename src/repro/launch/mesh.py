"""Production mesh definitions (functions — importing this module never
touches jax device state)."""

from __future__ import annotations

from repro.utils import compat

__all__ = ["make_production_mesh", "make_mesh_for", "make_tp_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes,
                            axis_types=compat.axis_type_auto(len(axes)))


def make_mesh_for(devices: int, model_parallel: int = 1, axes=("data", "model")):
    """Small helper for tests/examples on arbitrary device counts."""
    assert devices % model_parallel == 0
    return compat.make_mesh((devices // model_parallel, model_parallel), axes,
                            axis_types=compat.axis_type_auto(len(axes)))


def make_tp_mesh(tp: int):
    """One-axis ``("tp",)`` mesh for tensor-parallel sharded serving
    (serving/sharded.py).  The axis name is deliberately NOT "model":
    the logical-axis sharding rules and ``constrain()`` only react to
    pod/data/model, so the existing mesh machinery stays inert and the
    serving step's sharding is governed solely by its shard_map specs."""
    return compat.make_mesh((tp,), ("tp",),
                            axis_types=compat.axis_type_auto(1))
