"""Roofline-term derivation from a compiled dry-run artifact.

Hardware constants (assignment): TPU v5e-class chip —
  197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Conventions (post-SPMD HLO shapes are PER-DEVICE):
  compute term    = per_device_FLOPs / peak_FLOPs        [s]
  memory term     = per_device_dot_bytes / HBM_bw        [s]
  collective term = per_device_collective_bytes / link_bw [s]
(equivalent to the assignment's global/(chips*rate) forms.)

MODEL_FLOPS follows the assignment: 6*N*D for training (N = active params,
D = global tokens), 2*N*D for inference passes.
"""

from __future__ import annotations


from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / link (ICI)

__all__ = ["roofline_terms", "model_flops", "active_params",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW"]


def active_params(cfg, total_params: int) -> int:
    """Active parameter count (MoE: experts_per_token of n_experts)."""
    if not cfg.n_experts:
        return total_params
    per_expert = 3 * cfg.d_model * cfg.d_ff  # gated GLU expert
    all_expert = cfg.n_layers * cfg.n_experts * per_expert
    used_expert = cfg.n_layers * cfg.experts_per_token * per_expert
    return total_params - all_expert + used_expert


def model_flops(cfg, shape_name: str, total_params: int) -> float:
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    n_act = active_params(cfg, total_params)
    if sh["kind"] == "train":
        tokens = b * s
        return 6.0 * n_act * tokens
    if sh["kind"] == "prefill":
        return 2.0 * n_act * b * s
    # decode: one token per sequence per step
    return 2.0 * n_act * b


def roofline_terms(hlo_stats: dict, cfg, shape_name: str, total_params: int,
                   chips: int) -> dict:
    per_dev_flops = hlo_stats["flops"]
    per_dev_bytes = hlo_stats["dot_bytes"]
    per_dev_coll = hlo_stats["collective_bytes"]
    t_compute = per_dev_flops / PEAK_FLOPS
    t_memory = per_dev_bytes / HBM_BW
    t_collective = per_dev_coll / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_name, total_params)
    hlo_global_flops = per_dev_flops * chips
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_step_time_s": max(terms.values()),
        "model_flops": mf,
        "hlo_flops_global": hlo_global_flops,
        "useful_flops_ratio": (mf / hlo_global_flops) if hlo_global_flops else 0.0,
        "roofline_fraction": (
            (mf / PEAK_FLOPS / chips) / max(terms.values())
            if max(terms.values()) > 0 else 0.0),
        "per_device": {"flops": per_dev_flops, "dot_bytes": per_dev_bytes,
                       "collective_bytes": per_dev_coll},
    }
