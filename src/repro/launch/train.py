"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-3b-a800m \
        --smoke --steps 50 [--devices 8 --model-parallel 2]

On this CPU container use --smoke (reduced config); on a real slice drop it
and the assigned config trains on the production mesh.  XLA latency-hiding
flags for collective/compute overlap are set here (they only matter on
real hardware; harmless on CPU).
"""

import argparse
import os

# Compute/communication overlap: enable XLA's latency-hiding scheduler and
# async collectives before backend init (no-ops on CPU, critical on TPU).
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_enable_async_all_gather=true --xla_enable_async_all_reduce=true")
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    dev = os.environ.get("REPRO_HOST_DEVICES")
    if dev:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={dev} " + _flags)

import jax  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.launch.mesh import make_mesh_for, make_production_mesh  # noqa: E402
from repro.models import get_model_def  # noqa: E402
from repro.train.data import SyntheticLMData  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    from repro.launch.cli import add_backend_args, apply_backend_args
    add_backend_args(ap)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = apply_backend_args(cfg, args)
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = args.devices or len(jax.devices())
        mesh = make_mesh_for(n, args.model_parallel)

    md = get_model_def(cfg)
    shape = args.shape
    if args.smoke:
        from repro.configs.base import SHAPES
        SHAPES["smoke"] = dict(seq_len=128, global_batch=max(
            8, mesh.shape.get("data", 1)), kind="train")
        shape = "smoke"

    data = SyntheticLMData(cfg, shape, mesh)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir)
    trainer = Trainer(md, cfg, mesh, data, tcfg)
    trainer.run()
    for row in trainer.metrics_log:
        print(row)
    for ev in trainer.events:
        print("event:", ev)


if __name__ == "__main__":
    main()
