"""Deterministic fault injection for the serving stack (chaos harness).

A :class:`FaultPlan` is a seeded schedule of named fault points threaded
through :class:`~repro.serving.engine.ServeEngine`,
:class:`~repro.serving.kv_cache.PagedKVCache` and the gateway as
no-op-by-default hooks.  The plan owns a logical clock (`now`, one tick
per engine poll) and every probabilistic draw is a pure function of
``(seed, point, consultation-counter)``, so a chaos run is exactly
reproducible: same plan, same workload, same faults, same token streams.

Named fault points
------------------

``kv.exhaust``
    Level-triggered: while armed, the page allocator reports zero free
    pages (``_avail_for`` -> 0, ``_alloc_page`` -> None).  Admission
    stalls and speculative re-grow preempts, exactly as if the pool
    were full.  Level (not edge) semantics matter: the allocator's
    accounting check (`can_reserve`) and the subsequent allocation must
    see the *same* pool state within one tick.

``step.error``
    Edge-triggered: the fused device step raises
    :class:`InjectedFault` at dispatch, exercising crash containment
    (that tick's in-flight requests finish with
    ``finish_reason="error"``; the engine keeps serving).

``tick.delay``
    Edge-triggered: the engine sleeps ``delay_s`` before the tick,
    modelling a slow device / straggler shard.

``gateway.disconnect``
    Edge-triggered, consulted once per SSE event written: the gateway
    drops the client connection mid-stream (a disconnect storm),
    cancelling the request server-side.

Faults are described by :class:`FaultSpec` windows or the
:func:`parse_faults` mini-grammar used by the launch CLIs::

    parse_faults("step.error@3,kv.exhaust@1:4,tick.delay@0:20:p0.5:d0.01")

Engines built without a plan share the :data:`NO_FAULTS` singleton,
whose hooks all answer "no fault" without any bookkeeping.
"""

from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Dict, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Raised from an armed ``step.error`` fault point."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed window for a named fault point.

    The spec is armed on ticks ``start <= now < stop`` (``stop=None``
    means open-ended).  Within the window, edge-triggered points fire
    with probability ``prob`` per consultation (deterministic seeded
    draw), at most ``times`` times total (``None`` = unbounded);
    ``delay_s`` is the sleep injected by ``tick.delay``.
    """

    point: str
    start: int = 0
    stop: Optional[int] = None
    prob: float = 1.0
    times: Optional[int] = None
    delay_s: float = 0.01

    def armed(self, now: int) -> bool:
        return self.start <= now and (self.stop is None or now < self.stop)


class FaultPlan:
    """A seeded, deterministic schedule over named fault points.

    ``advance()`` is called once per engine tick; ``active`` /
    ``fires`` / ``raise_if`` / ``delay`` are the hooks consulted at the
    fault points.  All randomness derives from ``(seed, point,
    consultation-counter)`` via crc32, so replays are bit-exact and
    independent of wall clock, thread timing, or jax PRNG state.
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), *, seed: int = 0):
        self.specs: Tuple[FaultSpec, ...] = tuple(specs)
        self.seed = seed
        self.now = -1  # advance() runs before the first tick -> tick 0
        self.fired: collections.Counter = collections.Counter()
        self._calls: collections.Counter = collections.Counter()
        self._by_point: Dict[str, List[FaultSpec]] = {}
        for s in self.specs:
            self._by_point.setdefault(s.point, []).append(s)

    def __bool__(self) -> bool:
        return bool(self.specs)

    def __repr__(self) -> str:
        return f"FaultPlan({list(self.specs)!r}, seed={self.seed})"

    # -- clock ---------------------------------------------------------

    def advance(self) -> None:
        """Move the logical clock one tick (engine poll / step)."""
        self.now += 1

    # -- hooks ---------------------------------------------------------

    def active(self, point: str) -> bool:
        """Level-triggered query: is any window for `point` armed now?"""
        return any(s.armed(self.now) for s in self._by_point.get(point, ()))

    def _fire(self, point: str) -> Optional[FaultSpec]:
        specs = self._by_point.get(point)
        if not specs:
            return None
        call = self._calls[point]
        self._calls[point] += 1
        for s in specs:
            if not s.armed(self.now):
                continue
            if s.times is not None and self.fired[point] >= s.times:
                continue
            if s.prob < 1.0:
                draw = zlib.crc32(f"{self.seed}:{point}:{call}".encode())
                if draw / 0xFFFFFFFF >= s.prob:
                    continue
            self.fired[point] += 1
            return s
        return None

    def fires(self, point: str) -> bool:
        """Edge-triggered draw: does `point` fire on this consultation?"""
        return self._fire(point) is not None

    def raise_if(self, point: str) -> None:
        if self._fire(point) is not None:
            raise InjectedFault(f"injected fault {point!r} (tick {self.now})")

    def delay(self, point: str) -> float:
        """Seconds to sleep if `point` fires on this consultation."""
        s = self._fire(point)
        return s.delay_s if s is not None else 0.0


#: Shared empty plan: every hook answers "no fault".
NO_FAULTS = FaultPlan()


def parse_faults(text: Optional[str], *, seed: int = 0) -> FaultPlan:
    """Build a :class:`FaultPlan` from the CLI mini-grammar.

    Comma-separated entries ``point[@start[:stop][:pP][:xN][:dS]]``:
    ``@3`` arms tick 3 only, ``@1:4`` arms ticks [1, 4), a bare point
    is armed forever; ``:p0.5`` fires with probability 0.5 per
    consultation, ``:x2`` caps total firings at 2, ``:d0.05`` sets the
    ``tick.delay`` sleep to 50 ms.  Empty / None input returns
    :data:`NO_FAULTS`.
    """
    if not text:
        return NO_FAULTS
    specs = []
    for entry in text.split(","):
        entry = entry.strip()
        if not entry:
            continue
        point, _, rest = entry.partition("@")
        kw: dict = {"point": point, "start": 0, "stop": None}
        if rest:
            parts = rest.split(":")
            kw["start"] = int(parts[0])
            kw["stop"] = kw["start"] + 1
            for part in parts[1:]:
                if part.startswith("p"):
                    kw["prob"] = float(part[1:])
                elif part.startswith("x"):
                    kw["times"] = int(part[1:])
                elif part.startswith("d"):
                    kw["delay_s"] = float(part[1:])
                else:
                    kw["stop"] = None if part == "" else int(part)
        specs.append(FaultSpec(**kw))
    return FaultPlan(specs, seed=seed)
