"""Serving request surface: sampling params, lifecycle, streamed outputs.

One request = one generation job.  Its lifecycle is an explicit state
machine driven by the engine's scheduler:

    QUEUED -> PREFILLING -> DECODING -> FINISHED
       ^                       |            \
       +----- (preemption) ----+             CANCELLED (any live state)

Preemption (page pressure admitting a higher-priority request) sends a
DECODING request back to QUEUED with its generated tokens intact; on
re-admission the engine re-prefills prompt+generated (recompute-style
resume, pages were released at eviction).  ``cancel()`` is terminal and
frees pages immediately.

Streamed outputs: every generated token produces a ``RequestOutput``
record, delivered through ``engine.stream()`` (iterator) and/or the
request's ``on_token`` callback.  The final record of a request carries
``finished=True`` plus a ``finish_reason``:

    ``"length"``     max_new tokens generated
    ``"stop"``       a stop token id was generated (kept in the output)
    ``"cancelled"``  caller cancelled (or the client disconnected)
    ``"timeout"``    deadline_ms / queue_timeout_ms expired host-side
    ``"rejected"``   admission control refused the request
    ``"error"``      a device-step failure consumed the request's tick
                     (crash containment; the engine keeps serving)

The last three carry the human-readable cause in ``error``.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, List, Optional, Tuple

__all__ = ["SamplingParams", "RequestState", "Request", "RequestOutput"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    FINISHED = "finished"
    CANCELLED = "cancelled"

    @property
    def is_terminal(self) -> bool:
        return self in (RequestState.FINISHED, RequestState.CANCELLED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding policy, carried by the request (no more
    engine-global temperature).

    temperature <= 0 is greedy; top_k == 0 and top_p >= 1.0 disable the
    respective truncations.  ``stop`` token ids end the request the step
    they are generated (the stop token is kept in the output).

    Deadlines (both optional, milliseconds, enforced host-side in
    ``Scheduler.plan_tick`` — no device work is interrupted):
    ``deadline_ms`` bounds the request's total lifetime from submit;
    ``queue_timeout_ms`` bounds only the wait for FIRST admission (a
    preempted-and-requeued request has already been served, so only the
    deadline applies to it).  Expiry finishes the request with
    ``finish_reason="timeout"``.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop: Tuple[int, ...] = ()
    max_new: int = 32
    deadline_ms: Optional[float] = None
    queue_timeout_ms: Optional[float] = None

    def __post_init__(self):
        for name in ("deadline_ms", "queue_timeout_ms"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0, got {v}")
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if not isinstance(self.stop, tuple):
            object.__setattr__(self, "stop", tuple(self.stop))


@dataclasses.dataclass
class Request:
    """One generation job.  ``tokens`` accumulates generated ids; on
    preemption they are kept and the engine resumes by re-prefilling
    ``prompt + tokens``."""

    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    rid: Optional[int] = None  # auto-assigned by the engine when None
    priority: int = 0  # higher preempts lower under page pressure
    on_token: Optional[Callable[["RequestOutput"], None]] = None

    # engine-managed state
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    error: Optional[str] = None  # cause for timeout/rejected/error finishes
    prefix_matched: int = 0  # tokens served from shared prefix pages at
    #                          the last admission (0 = no sharing)

    @property
    def total_tokens(self) -> int:
        return len(self.prompt) + len(self.tokens)


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """One streamed step of one request (engine.stream() / on_token)."""

    rid: int
    token: Optional[int]  # newest generated id (None for token-less
    #                       terminal events, e.g. cancellation)
    index: int  # number of generated tokens so far
    state: RequestState
    finished: bool
    finish_reason: Optional[str]
    tokens: Tuple[int, ...]  # snapshot of all generated ids
    error: Optional[str] = None  # cause for timeout/rejected/error finishes
