"""Network serving gateway: asyncio HTTP/SSE frontend over ``ServeEngine``.

This is the layer that points live traffic at the paged serving core.  It
is stdlib-only (``asyncio.start_server`` + hand-rolled HTTP/1.1), so the
repo's dependency pins stay jax+numpy.

Architecture — one engine thread, many asyncio clients:

  * ``EngineRunner`` (a thread) owns ALL engine interaction.  It drains a
    thread-safe control queue (submissions, cancellations) at the top of
    every iteration and then calls ``engine.poll()`` — one overlapped
    (dispatch-ahead) engine tick.  New requests therefore join the running
    batch at the next tick: continuous-batching admission under live
    traffic, never a stop-the-world drain.

  * each HTTP handler coroutine builds a ``Request`` from the JSON body,
    installs an ``on_token`` callback that trampolines every
    ``RequestOutput`` onto the event loop (``loop.call_soon_threadsafe``),
    and streams them to the client as server-sent events.  A client
    disconnect mid-stream cancels the request — ``engine.cancel(rid)``
    runs on the engine thread and frees the request's pages immediately.

  * ``GatewayMetrics`` accumulates per-request TTFT (submit -> first
    token) and TPOT (inter-token) histograms on the engine thread, plus
    request/token/prefix-sharing counters.  ``GET /metrics`` surfaces
    them next to the engine's own counters (``readbacks``, ``blocked_s``,
    ``peak_pages``, ``preemptions``, pool residency).

Endpoints:

  * ``POST /v1/generate`` — body ``{"prompt": [ids], "max_new": N,
    "temperature": t, "top_k": k, "top_p": p, "stop": [ids],
    "priority": n, "deadline_ms": D, "queue_timeout_ms": Q}``; responds
    ``text/event-stream``, one ``data: {json}`` event per generated
    token (the final event carries ``"finished": true``, a
    ``finish_reason``, the full token list, and — for
    timeout/rejected/error finishes — the cause under ``"error"``).
  * ``GET /healthz`` — liveness + model/backend identity.
  * ``GET /metrics`` — JSON metrics snapshot.

Backpressure (admission control BEFORE the request crosses onto the
engine thread): a request that can never fit the engine gets HTTP 503;
a full bounded queue (``ServeEngine(max_queue=...)``) gets HTTP 429 with
a ``Retry-After`` header.  Requests the engine itself rejects finish
with ``finish_reason="rejected"`` and the reason string in the SSE
error field.
"""

from __future__ import annotations

import asyncio
import bisect
import collections
import json
import logging
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.faults import NO_FAULTS, FaultPlan
from repro.serving.request import Request, RequestOutput, RequestState, SamplingParams

__all__ = [
    "EngineRunner",
    "Gateway",
    "GatewayMetrics",
    "LatencyStats",
    "request_from_json",
    "serve_background",
]

log = logging.getLogger("repro.serving.gateway")

_BUCKETS_MS = (
    1.0,
    2.0,
    5.0,
    10.0,
    20.0,
    50.0,
    100.0,
    200.0,
    500.0,
    1000.0,
    2000.0,
    5000.0,
    10000.0,
    30000.0,
)


class LatencyStats:
    """Streaming latency accumulator: log-spaced histogram buckets plus a
    bounded sample ring for percentile estimates (p50/p99 over the most
    recent ``cap`` observations)."""

    def __init__(self, cap: int = 8192):
        self.count = 0
        self.total_ms = 0.0
        self.buckets = [0] * (len(_BUCKETS_MS) + 1)
        self._cap = cap
        self._samples: List[float] = []

    def observe(self, ms: float) -> None:
        self.count += 1
        self.total_ms += ms
        self.buckets[bisect.bisect_left(_BUCKETS_MS, ms)] += 1
        if len(self._samples) < self._cap:
            self._samples.append(ms)
        else:
            self._samples[self.count % self._cap] = ms

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        s = sorted(self._samples)
        idx = min(len(s) - 1, int(round(q / 100.0 * (len(s) - 1))))
        return s[idx]

    def snapshot(self) -> dict:
        hist = {}
        for le, n in zip(_BUCKETS_MS, self.buckets):
            hist[f"le_{le:g}"] = n
        hist["inf"] = self.buckets[-1]
        return {
            "count": self.count,
            "mean_ms": self.total_ms / max(self.count, 1),
            "p50_ms": self.percentile(50.0),
            "p99_ms": self.percentile(99.0),
            "buckets_ms": hist,
        }


class GatewayMetrics:
    """Request-level serving metrics, written from the engine thread."""

    def __init__(self):
        self._lock = threading.Lock()
        self.ttft = LatencyStats()
        self.tpot = LatencyStats()
        self.submitted = 0
        self.completed = 0
        self.cancelled = 0
        self.rejected = 0
        self.timed_out = 0
        self.errored = 0
        self.tokens_out = 0
        self.prompt_tokens = 0
        self.prefix_hit_tokens = 0
        # routed (rid, index) order — the continuous-batching interleave
        # record the gateway tests assert on; bounded for long-lived servers
        self.event_log = collections.deque(maxlen=4096)

    def record_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def record_rejected(self) -> None:
        """A request vetoed at the gateway (429/503): it never reached
        the engine thread, so no RequestOutput will account for it."""
        with self._lock:
            self.rejected += 1

    def record_output(self, req: Request, rec: dict, out: RequestOutput) -> None:
        now = time.perf_counter()
        with self._lock:
            if out.token is not None:
                self.tokens_out += 1
                self.event_log.append((out.rid, out.index))
                if rec["t_prev"] is None:
                    self.ttft.observe((now - rec["t_submit"]) * 1e3)
                else:
                    self.tpot.observe((now - rec["t_prev"]) * 1e3)
                rec["t_prev"] = now
            if out.finished:
                if out.finish_reason == "cancelled":
                    self.cancelled += 1
                elif out.finish_reason == "rejected":
                    self.rejected += 1
                elif out.finish_reason == "timeout":
                    self.timed_out += 1
                elif out.finish_reason == "error":
                    self.errored += 1
                else:
                    self.completed += 1
                self.prompt_tokens += len(req.prompt)
                self.prefix_hit_tokens += req.prefix_matched

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "requests": {
                    "submitted": self.submitted,
                    "completed": self.completed,
                    "cancelled": self.cancelled,
                    "rejected": self.rejected,
                    "timed_out": self.timed_out,
                    "errored": self.errored,
                    "tokens_out": self.tokens_out,
                    "prompt_tokens": self.prompt_tokens,
                    "prefix_hit_tokens": self.prefix_hit_tokens,
                    "prefix_hit_rate": (
                        self.prefix_hit_tokens / max(self.prompt_tokens, 1)
                    ),
                },
                "ttft_ms": self.ttft.snapshot(),
                "tpot_ms": self.tpot.snapshot(),
            }


class EngineRunner(threading.Thread):
    """The engine thread: the ONLY place ``ServeEngine`` is touched.

    Clients hand in fully-built ``Request``s through ``submit(req, sink)``
    — ``sink`` is called once per ``RequestOutput`` ON THIS THREAD (wrap
    with ``loop.call_soon_threadsafe`` to cross into asyncio) — and
    ``cancel(rid)``.  Both enqueue onto thread-safe deques the run loop
    drains before each ``engine.poll()``, so admission, preemption, COW
    prefix matching, and page accounting all stay single-threaded.
    """

    def __init__(self, engine, *, idle_wait_s: float = 0.02):
        super().__init__(name="engine-runner", daemon=True)
        self.engine = engine
        self.metrics = GatewayMetrics()
        self.idle_wait_s = idle_wait_s
        self._submit_q: collections.deque = collections.deque()
        self._cancel_q: collections.deque = collections.deque()
        self._wake = threading.Event()
        self._stopping = threading.Event()
        self._lock = threading.Lock()
        # start above any rids the engine already assigned (e.g. warmup
        # requests run before the runner thread took over)
        self._next_rid = engine.sched._next_rid
        self._live: set = set()

    # -- client-thread surface ------------------------------------------------
    def submit(self, req: Request, sink: Callable[[RequestOutput], None]) -> int:
        """Queue ``req`` for the engine; returns its rid immediately (the
        engine thread performs the actual admission).  ``sink`` receives
        every streamed output of the request, including the terminal one."""
        with self._lock:
            if req.rid is None:
                req.rid = self._next_rid
            self._next_rid = max(self._next_rid, req.rid + 1)
            self._live.add(req.rid)
        rec = {"t_submit": time.perf_counter(), "t_prev": None}
        metrics = self.metrics
        metrics.record_submit()

        def on_token(out: RequestOutput, _req=req, _rec=rec, _sink=sink) -> None:
            metrics.record_output(_req, _rec, out)
            if out.finished:
                with self._lock:
                    self._live.discard(out.rid)
            _sink(out)

        req.on_token = on_token
        self._submit_q.append(req)
        self._wake.set()
        return req.rid

    def cancel(self, rid: int) -> None:
        self._cancel_q.append(rid)
        self._wake.set()

    def admission_veto(self, req: Request) -> Optional[Tuple[str, bool]]:
        """Admission control BEFORE ``req`` crosses onto the engine
        thread: ``None`` to admit, else ``(reason, retryable)`` —
        retryable means the bounded queue is full right now (HTTP 429 +
        Retry-After), non-retryable means the request can never be
        served by this engine (HTTP 503).  Reads scheduler state without
        locking: queue length is a monotonic-enough signal for
        backpressure, and the engine-thread submit path re-checks
        authoritatively."""
        sched = self.engine.sched
        reason = sched.never_fit(req)
        if reason is not None:
            return reason, False
        if sched.queue_full(extra=len(self._submit_q)):
            return (
                f"queue full ({len(sched.queue)} queued, "
                f"max_queue {sched.max_queue})",
                True,
            )
        return None

    def stop(self, timeout: float = 30.0) -> bool:
        """Stop the engine thread; returns False (and logs) if the join
        timed out — the thread is still running, NOT cleanly stopped."""
        self._stopping.set()
        self._wake.set()
        self.join(timeout)
        if self.is_alive():
            log.error(
                "engine thread failed to stop within %.1fs; "
                "it is still running (daemon thread, will not block exit)",
                timeout,
            )
            return False
        return True

    # -- engine-thread loop ---------------------------------------------------
    def _drain_control(self) -> None:
        cancels = []
        while self._cancel_q:
            cancels.append(self._cancel_q.popleft())
        pending = []
        while self._submit_q:
            pending.append(self._submit_q.popleft())
        cancelled = set(cancels)
        for req in pending:
            if req.rid in cancelled:
                # cancel raced ahead of the submit drain: never admit, but
                # still surface the terminal event through the sink
                cancelled.discard(req.rid)
                req.state = RequestState.CANCELLED
                req.finish_reason = "cancelled"
                out = RequestOutput(
                    rid=req.rid,
                    token=None,
                    index=0,
                    state=RequestState.CANCELLED,
                    finished=True,
                    finish_reason="cancelled",
                    tokens=(),
                )
                if req.on_token:
                    req.on_token(out)
                continue
            try:
                self.engine.submit(req)
            except ValueError as e:
                # rejected by admission control (RejectionError /
                # QueueFullError / invalid request): the gateway
                # pre-vetoes, this is the engine-thread authority —
                # surface the reason, keep serving
                req.state = RequestState.FINISHED
                req.finish_reason = "rejected"
                req.error = str(e)
                out = RequestOutput(
                    rid=req.rid,
                    token=None,
                    index=0,
                    state=RequestState.FINISHED,
                    finished=True,
                    finish_reason="rejected",
                    tokens=(),
                    error=str(e),
                )
                if req.on_token:
                    req.on_token(out)
        for rid in cancelled:
            self.engine.cancel(rid)  # terminal event routed via on_token

    def run(self) -> None:
        eng = self.engine
        while not self._stopping.is_set():
            self._drain_control()
            try:
                eng.poll()
            except Exception as e:
                # device-step failures are contained INSIDE poll()
                # (engine._fail_tick); anything reaching here is a
                # host-side planning bug.  Fail the requests it touched
                # and keep the thread alive — a serving gateway must not
                # die to one poisoned tick.
                log.exception(
                    "engine poll raised (host-side bug); failing active "
                    "requests and continuing"
                )
                try:
                    eng.sched.fail_active(f"{type(e).__name__}: {e}")
                except Exception:
                    log.exception("containment itself failed")
                self._wake.wait(self.idle_wait_s)
            if not (eng.has_work or eng.has_pending):
                if self._wake.wait(self.idle_wait_s):
                    self._wake.clear()
        with self._lock:
            live = list(self._live)
        for rid in live:
            eng.cancel(rid)


def request_from_json(spec: dict, *, max_len: Optional[int] = None) -> Request:
    """Build a validated ``Request`` from a ``POST /v1/generate`` body.

    Raises ``ValueError`` on malformed input (the gateway maps it to 400)
    so invalid requests never reach the engine thread.
    """
    if not isinstance(spec, dict):
        raise ValueError("request body must be a JSON object")
    prompt = spec.get("prompt")
    if (
        not isinstance(prompt, list)
        or not prompt
        or not all(isinstance(t, int) and not isinstance(t, bool) for t in prompt)
    ):
        raise ValueError("'prompt' must be a non-empty list of token ids")
    stop = spec.get("stop", ())
    if not isinstance(stop, (list, tuple)):
        raise ValueError("'stop' must be a list of token ids")
    deadline = spec.get("deadline_ms")
    queue_timeout = spec.get("queue_timeout_ms")
    sampling = SamplingParams(
        temperature=float(spec.get("temperature", 0.0)),
        top_k=int(spec.get("top_k", 0)),
        top_p=float(spec.get("top_p", 1.0)),
        stop=tuple(int(t) for t in stop),
        max_new=int(spec.get("max_new", 32)),
        deadline_ms=None if deadline is None else float(deadline),
        queue_timeout_ms=None if queue_timeout is None else float(queue_timeout),
    )
    if max_len is not None and len(prompt) + sampling.max_new > max_len:
        raise ValueError(
            f"prompt+max_new {len(prompt) + sampling.max_new} exceeds "
            f"engine max_len {max_len}"
        )
    return Request(
        prompt=list(prompt),
        sampling=sampling,
        priority=int(spec.get("priority", 0)),
    )


def _sse_event(out: RequestOutput) -> bytes:
    payload = {
        "rid": out.rid,
        "token": out.token,
        "index": out.index,
        "state": out.state.value,
        "finished": out.finished,
        "finish_reason": out.finish_reason,
    }
    if out.finished:
        payload["tokens"] = list(out.tokens)
        if out.error is not None:
            payload["error"] = out.error
    return b"data: " + json.dumps(payload).encode() + b"\n\n"


class Gateway:
    """The asyncio HTTP server; owns an ``EngineRunner``.

    ``await Gateway(engine).start()`` binds the socket (``port=0`` picks a
    free one — read it back from ``.port``) and starts the engine thread;
    ``await serve_forever()`` blocks; ``await aclose()`` shuts both down.
    """

    def __init__(
        self,
        engine,
        *,
        host: str = "127.0.0.1",
        port: int = 8000,
        faults: Optional[FaultPlan] = None,
    ):
        self.engine = engine
        self.runner = EngineRunner(engine)
        self.host = host
        self.port = port
        # gateway-level fault points (client disconnect storms); defaults
        # to the engine's plan so one --faults flag arms the whole stack
        self.faults = faults if faults is not None else getattr(
            engine, "faults", NO_FAULTS
        )
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "Gateway":
        if not self.runner.is_alive():
            self.runner.start()
        self._server = await asyncio.start_server(self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def serve_forever(self) -> None:
        await self._server.serve_forever()

    async def aclose(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if not self.runner.stop():
            raise RuntimeError(
                "engine thread did not stop cleanly (join timed out)"
            )

    # -- request handling -----------------------------------------------------
    async def _handle(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter
    ) -> None:
        try:
            try:
                head = await reader.readuntil(b"\r\n\r\n")
            except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
                return
            line, _, rest = head.partition(b"\r\n")
            parts = line.decode("latin-1").split()
            if len(parts) < 2:
                return
            method, path = parts[0].upper(), parts[1]
            headers: Dict[str, str] = {}
            for raw in rest.decode("latin-1").split("\r\n"):
                name, sep, value = raw.partition(":")
                if sep:
                    headers[name.strip().lower()] = value.strip()
            if method == "POST" and path == "/v1/generate":
                length = int(headers.get("content-length", "0"))
                body = await reader.readexactly(length) if length else b""
                await self._generate(reader, writer, body)
            elif method == "GET" and path == "/healthz":
                await _send_json(writer, 200, self._health())
            elif method == "GET" and path == "/metrics":
                await _send_json(writer, 200, self._metrics())
            else:
                await _send_json(writer, 404, {"error": f"no route {method} {path}"})
        except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _generate(
        self, reader: asyncio.StreamReader, writer: asyncio.StreamWriter, body: bytes
    ) -> None:
        try:
            spec = json.loads(body.decode() or "null")
            req = request_from_json(spec, max_len=self.engine.max_len)
        except (ValueError, TypeError) as e:
            await _send_json(writer, 400, {"error": str(e)})
            return
        veto = self.runner.admission_veto(req)
        if veto is not None:
            reason, retryable = veto
            self.runner.metrics.record_rejected()
            if retryable:  # bounded queue full NOW: back off and retry
                await _send_json(
                    writer,
                    429,
                    {"error": reason, "finish_reason": "rejected", "retry_after_s": 1},
                    headers=(("Retry-After", "1"),),
                )
            else:  # can NEVER be served by this engine
                await _send_json(
                    writer, 503, {"error": reason, "finish_reason": "rejected"}
                )
            return
        loop = asyncio.get_running_loop()
        queue: asyncio.Queue = asyncio.Queue()

        def sink(out: RequestOutput) -> None:
            loop.call_soon_threadsafe(queue.put_nowait, out)

        rid = self.runner.submit(req, sink)
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n"
        )
        # half-close watch: a client that goes away mid-stream hits EOF
        # here long before a write fails, so its pages free immediately
        gone = loop.create_task(_watch_disconnect(reader))
        try:
            while True:
                getter = loop.create_task(queue.get())
                done, _ = await asyncio.wait(
                    {getter, gone}, return_when=asyncio.FIRST_COMPLETED
                )
                if getter not in done:
                    getter.cancel()
                    self.runner.cancel(rid)
                    return
                out = getter.result()
                writer.write(_sse_event(out))
                await writer.drain()
                if out.finished:
                    return
                if self.faults.fires("gateway.disconnect"):
                    # chaos: simulate the client vanishing mid-stream —
                    # drop the connection and cancel server-side, exactly
                    # the disconnect path a real storm exercises
                    self.runner.cancel(rid)
                    return
        except (ConnectionResetError, BrokenPipeError):
            self.runner.cancel(rid)
        finally:
            gone.cancel()

    def _health(self) -> dict:
        cfg = self.engine.cfg
        layout = cfg.uniform_backend or ",".join(cfg.layer_backends)
        return {
            "status": "ok",
            "model": cfg.name,
            "backend": layout,
            "mode": self.engine.mode,
            "max_batch": self.engine.max_batch,
            "max_len": self.engine.max_len,
        }

    def _metrics(self) -> dict:
        eng = self.engine
        snap = self.runner.metrics.snapshot()
        snap["engine"] = {
            "ticks": eng.ticks,
            "readbacks": eng.readbacks,
            "blocked_s": eng.blocked_s,
            "peak_pages": eng.peak_pages,
            # per-device page-pool occupancy (ONE host page table; under
            # tensor parallelism each device holds all pages at 1/tp of
            # the head slice — serving/sharded.py)
            "tp": eng.tp,
            "page_pool": eng.kv.occupancy(eng.tp),
            "preemptions": eng.preemptions,
            # TTFT attribution: chunked-prefill activity next to the
            # spec/preemption counters (flat under a TTFT regression =>
            # decode/queueing problem, rising => prefill path)
            "prefill_tokens": eng.prefill_tokens,
            "prefill_ticks": eng.prefill_ticks,
            "spec_proposed": eng.spec_proposed,
            "spec_accepted": eng.spec_accepted,
            "spec_acceptance": eng.spec_acceptance,
            "free_pages": eng.kv.free_pages,
            "pool_pages": eng.kv.n_pages - 1,
            "queue_depth": len(eng.queue),
            "active": sum(r is not None for r in eng.active),
            # robustness counters: contained device-tick failures plus
            # the scheduler's admission/deadline enforcement tallies
            "tick_errors": eng.tick_errors,
            "timeouts": eng.sched.timeouts,
            "rejections": eng.sched.rejections,
        }
        return snap


async def _watch_disconnect(reader: asyncio.StreamReader) -> None:
    while True:
        try:
            data = await reader.read(1024)
        except (ConnectionResetError, BrokenPipeError, OSError):
            return
        if not data:
            return


async def _send_json(
    writer: asyncio.StreamWriter,
    status: int,
    obj: dict,
    headers: Tuple[Tuple[str, str], ...] = (),
) -> None:
    reason = {
        200: "OK",
        400: "Bad Request",
        404: "Not Found",
        429: "Too Many Requests",
        503: "Service Unavailable",
    }.get(status, "Error")
    body = json.dumps(obj, default=float).encode()
    extra = "".join(f"{name}: {value}\r\n" for name, value in headers)
    writer.write(
        f"HTTP/1.1 {status} {reason}\r\n"
        f"Content-Type: application/json\r\n"
        f"Content-Length: {len(body)}\r\n"
        f"{extra}"
        f"Connection: close\r\n\r\n".encode() + body
    )
    await writer.drain()


class _BackgroundGateway:
    """Handle to a gateway running on its own thread + event loop."""

    def __init__(self, box: dict, thread: threading.Thread):
        self._box = box
        self._thread = thread

    @property
    def gateway(self) -> Gateway:
        return self._box["gateway"]

    @property
    def runner(self) -> EngineRunner:
        return self.gateway.runner

    @property
    def port(self) -> int:
        return self.gateway.port

    @property
    def url(self) -> str:
        return f"http://{self.gateway.host}:{self.gateway.port}"

    def stop(self, timeout: float = 30.0) -> bool:
        """Stop the gateway thread; returns False (and logs) when the
        join times out instead of pretending a clean shutdown."""
        loop, stop = self._box["loop"], self._box["stop"]
        loop.call_soon_threadsafe(stop.set)
        self._thread.join(timeout)
        if self._thread.is_alive():
            log.error(
                "gateway thread failed to stop within %.1fs; "
                "it is still running",
                timeout,
            )
            return False
        return True


def serve_background(
    engine,
    *,
    host: str = "127.0.0.1",
    port: int = 0,
    timeout: float = 60.0,
    faults: Optional[FaultPlan] = None,
) -> _BackgroundGateway:
    """Start a gateway on a daemon thread (its own asyncio loop); returns
    once the socket is bound.  Used by the tests and the load benchmark's
    self-hosted mode."""
    started = threading.Event()
    box: dict = {}

    def _main() -> None:
        async def body() -> None:
            gw = Gateway(engine, host=host, port=port, faults=faults)
            await gw.start()
            box["gateway"] = gw
            box["loop"] = asyncio.get_running_loop()
            box["stop"] = asyncio.Event()
            started.set()
            await box["stop"].wait()
            await gw.aclose()

        asyncio.run(body())

    thread = threading.Thread(target=_main, name="gateway", daemon=True)
    thread.start()
    if not started.wait(timeout):
        raise RuntimeError("gateway failed to start")
    return _BackgroundGateway(box, thread)
