"""Self-speculative decoding: binary-draft / target-verify multi-token steps.

CAMformer's thesis — binarized associative scoring is a near-lossless,
radically cheaper stand-in for dense attention — makes the ``binary``
backend a FREE draft model for the very stack it approximates: the same
weights, every layer forced to ``cfg.spec_backend``, drafting from its
own cheap paged cache.  Each tick the drafter proposes up to ``spec_k``
tokens per DECODING slot and the target stack (dense / camformer /
mixed, unchanged) verifies all ``k+1`` positions in ONE fused device
step over the existing Sq>1 chunked-prefill seam (``offsets`` /
``scale_base``), so a tick that accepts ``a`` drafts emits ``a+1``
tokens for one target forward.

Exactness (keyed-sample-match acceptance)
-----------------------------------------

The emitted tokens are the TARGET's keyed samples, never the drafter's:
position ``L+j`` of the verify pass samples ``s_j`` with
``sample_step_keyed`` at generated-token index ``i+j`` — a pure function
of ``(seed, rid, index)``, exactly the draw sequential decode would
make at that index from the same cache state.  Draft ``d_j`` is
accepted iff ``d_j == s_{j-1}`` (it matches what would have been
emitted anyway), and acceptance stops at the first mismatch, so the
accepted prefix ``s_0 .. s_acc`` is token-for-token the sequential
output for ANY temperature — greedy reduces to standard greedy
speculative decoding, and ``spec_k=0`` never enters this module.  The
drafter maximizes its hit rate by sampling with the SAME keyed draws at
the SAME indices (shared-randomness coupling), so where the binary
approximation agrees with the target, the draft is accepted by
construction.

Cache discipline
----------------

Target and drafter share ONE page table / allocator: the drafter's pool
(``page_specs`` of the draft config) uses the same physical page ids,
so admission, COW prefix forks, preemption, and rollback are planned
once.  The drafter runs ``m = spec_k+1`` single-token steps so its pool
stays in positional lockstep with the target's verify writes (the last
step's sample is discarded; per-row steps beyond ``n_tok`` run with
``kv_len == 0`` — the backend inert-row contract — so they touch
neither pages nor running statistics).  Rejected suffixes roll back on
the HOST via ``PagedKVCache.truncate_to`` (scheduler ``resolve_spec``);
device-side, the rejected positions hold garbage beyond ``kv_len`` —
invisible to masked attention and overwritten by the next tick.

``k_scale`` (binary/camformer softmax-temperature bookkeeping) keeps
sequential-decode semantics throughout: the verify pass runs under
``spec_verify`` — each chunk column attends with the running scale AT
ITS OWN POSITION and the chunk's per-position key means are stashed in
the ``k_means`` pool leaf — so ``repair_k_scale`` reconstructs the
running mean at the accepted length exactly, and ``select_k_scale``
rolls the drafter back by picking its per-step snapshot.  Without this
the chunk-granular scale (a mean contaminated by the chunk's rejected
future keys) perturbs verify logits at the percent level and breaks
greedy token-for-token identity with the sequential loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.serving import sampler as S

__all__ = ["draft_config", "sample_positions_keyed", "accept_prefix",
           "repair_k_scale", "select_k_scale", "build_spec_prefill",
           "build_spec_step"]


def draft_config(cfg):
    """The drafter's model config: the SAME architecture with every
    layer's attention forced to ``cfg.spec_backend`` (weights are
    shared; only the attention realization and its page layout change)."""
    return cfg.replace(layer_backends=None, attn_backend=cfg.spec_backend)


def sample_positions_keyed(logits, keys, index, temps, top_ks, top_ps):
    """``sample_step_keyed`` over every position of a verify batch.

    logits: (B, M, V); index: (B, M) generated-token index per position;
    keys/temps/top_ks/top_ps: per-slot (B, ...) rows shared across
    positions.  Returns (B, M) int32 — column ``j`` is the draw the
    sequential loop would make at ``index[:, j]``.
    """
    def one(lg, ix):
        return S.sample_step_keyed(lg, keys, ix, temps, top_ks, top_ps)

    return jax.vmap(one, in_axes=(1, 1), out_axes=1)(
        logits, index.astype(jnp.int32))


def accept_prefix(drafts, samples, n_tok):
    """Length of the accepted prefix per row, INCLUDING the bonus token.

    drafts: (B, M) the verify inputs (column 0 is the previous tick's
    token, columns 1.. the draft proposals); samples: (B, M) the
    target's keyed samples; n_tok: (B,) valid positions per row.
    Draft ``drafts[:, j]`` is accepted iff it equals ``samples[:, j-1]``
    and every earlier draft was accepted; the return value
    ``n_valid = accepted + 1`` counts the emitted tokens
    ``samples[:, :n_valid]`` (0 for rows with ``n_tok == 0``).
    """
    b, m = drafts.shape
    if m > 1:
        j = jnp.arange(1, m, dtype=jnp.int32)[None]
        ok = (drafts[:, 1:] == samples[:, :-1]) & (j < n_tok[:, None])
        acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    else:
        acc = jnp.zeros((b,), jnp.int32)
    return jnp.where(n_tok > 0, acc + 1, 0).astype(jnp.int32)


def repair_k_scale(new, old, pos, base, n_tok, n_valid):
    """Roll every ``k_scale`` leaf back from ``n_tok`` written positions
    to the ``n_valid`` accepted ones — EXACTLY.

    The verify pass merged this tick's chunk into the running mean and
    stashed the chunk's per-position key means in the ``k_means`` leaf
    (backends under ``spec_verify``; see ``_chunk_scale_seq``).  The
    repaired scale is therefore reconstructible at any accepted length:
    ``s' = (s0*n0 + sum(means[:kept])) / (n0 + kept)`` with ``n0 =
    pos - base`` prior counted positions and ``kept = n_valid`` — the
    value a sequential decode loop would have stored after its last
    accepted step.  Rows with nothing rejected (or inert rows,
    ``n_tok == 0``) keep the post-verify value bit-exactly.

    ``new``/``old`` are the post-/pre-step cache trees (uniform layer-
    stacked dict or per-layer tuple); ``k_scale`` leaves have shape
    (..., B, H), ``k_means`` (..., B, H, m), and the per-slot stats
    broadcast over leading layer axes.
    """
    n0 = (pos - base).astype(jnp.float32)[:, None]
    w = n_tok.astype(jnp.float32)[:, None]
    kept = n_valid.astype(jnp.float32)[:, None]
    exact = (kept >= w) | (w <= 0)

    def one(nl, ol):
        if "k_scale" not in nl or "k_means" not in nl:
            return nl
        s1, s0 = nl["k_scale"], ol["k_scale"]
        cum = jnp.cumsum(nl["k_means"], axis=-1)
        m = cum.shape[-1]
        ix = jnp.clip(n_valid - 1, 0, m - 1).astype(jnp.int32)
        ix = jnp.broadcast_to(ix.reshape((-1, 1, 1)), cum.shape[:-1] + (1,))
        kept_sum = jnp.take_along_axis(cum, ix, axis=-1)[..., 0]
        fixed = (s0 * n0 + kept_sum) / jnp.maximum(n0 + kept, 1.0)
        return {**nl, "k_scale": jnp.where(exact, s1, fixed)}

    if isinstance(new, tuple):
        return tuple(one(nl, ol) for nl, ol in zip(new, old))
    return one(new, old)


def _kscales(tree):
    """The ``k_scale`` leaves of a cache tree (layer-structural snapshot;
    ``None`` per layer when the backend keeps no running scale)."""
    if isinstance(tree, tuple):
        return tuple(layer.get("k_scale") for layer in tree)
    return tree.get("k_scale")


def select_k_scale(final, snaps, n_valid):
    """Drafter-side rollback: pick each row's ``k_scale`` from the step
    snapshot of its LAST accepted draft step.

    The draft loop runs sequentially, so the exact rolled-back scale is
    simply the value after step ``n_valid - 1`` — no reconstruction.
    ``snaps`` is the per-step list of ``_kscales`` snapshots (length m);
    rows with ``n_valid == 0`` were inert all tick, so snapshot 0 holds
    their untouched pre-tick value.
    """
    idx = jnp.clip(n_valid - 1, 0, len(snaps) - 1).astype(jnp.int32)

    def one(layer, *vals):
        if "k_scale" not in layer:
            return layer
        stk = jnp.stack(vals, axis=-1)  # (..., B, H, m)
        ix = jnp.broadcast_to(idx.reshape((-1, 1, 1)),
                              stk.shape[:-1] + (1,))
        return {**layer,
                "k_scale": jnp.take_along_axis(stk, ix, axis=-1)[..., 0]}

    if isinstance(final, tuple):
        return tuple(one(layer, *(s[i] for s in snaps))
                     for i, layer in enumerate(final))
    return one(final, *snaps)


def build_spec_prefill(md, cfg, dcfg, hot: bool):
    """The fused prefill step with speculation on: the target prefill
    (unchanged — its last-token sample is the slot's first token) plus a
    drafter-stack prefill over the same chunk batch, so the draft pool
    holds the prompt KV before the slot's first speculative tick."""

    def fn(params, tokens, lens, offsets, scale_base, caches, dcaches, pt,
           keys, index, temps, top_ks, top_ps):
        batch = {"tokens": tokens, "lens": lens, "offsets": offsets,
                 "scale_base": scale_base}
        logits, caches = md.prefill_paged(params, batch, caches, pt, cfg)
        _, dcaches = md.prefill_paged(params, batch, dcaches, pt, dcfg)
        if hot:
            first = S.sample_step_keyed(logits, keys, index, temps,
                                        top_ks, top_ps)
        else:
            first = S.greedy(logits)
        return first, caches, dcaches

    return fn


def build_spec_step(md, cfg, dcfg, m: int, hot: bool):
    """The fused speculative decode step (ONE jit per tick).

    Per live row with ``n_tok`` dispatched indices starting at position
    ``pos`` and generated-token index ``index``:

      1. DRAFT: ``m`` sequential drafter steps (binary stack, own pool,
         same page table), sampling proposals with the target's keyed
         draws at the same indices; step ``j`` past ``n_tok`` is inert.
      2. VERIFY: the target scores all ``m`` positions in one Sq>1 pass
         (``verify_paged`` over the chunked-prefill seam) and draws its
         keyed samples ``s_0..s_{m-1}``.
      3. ACCEPT: longest prefix of drafts matching the samples;
         ``n_valid = accepted + 1`` tokens are emitted.
      4. REPAIR: ``k_scale`` leaves of BOTH pools rescale to the
         accepted count; the token buffer takes the last VALID sample
         (rows outside this tick keep their buffered token).

    Returns ``(packed (B, m+1) int32 — samples ++ n_valid — the tick's
    single readback, tok_buf (B,), caches, dcaches)``.
    """

    def fn(params, tok_prev, fresh, fresh_mask, live_mask, pos, n_tok,
           caches, dcaches, pt, base, keys, index, temps, top_ks, top_ps):
        pos = pos.astype(jnp.int32)
        n_tok = n_tok.astype(jnp.int32)
        index = index.astype(jnp.int32)
        t0 = jnp.where(live_mask,
                       jnp.where(fresh_mask, fresh, tok_prev), 0)
        caches0 = caches

        # -- 1. draft: m lockstep single-token steps ------------------
        toks = [t0]
        tok = t0
        snaps = []  # per-step k_scale snapshots for exact rollback
        for j in range(m):
            kvl = jnp.where(live_mask & (j < n_tok), pos + j + 1, 0)
            dlogits, dcaches = md.decode_paged(
                params, tok, pos + j, kvl, dcaches, pt, dcfg, base=base)
            snaps.append(_kscales(dcaches))
            if j < m - 1:  # the last step only writes lockstep KV
                if hot:
                    tok = S.sample_step_keyed(dlogits, keys, index + j,
                                              temps, top_ks, top_ps)
                else:
                    tok = S.greedy(dlogits)
                toks.append(tok)
        drafts = jnp.stack(toks, axis=1)  # (B, m)

        # -- 2. verify: one fused Sq>1 target pass --------------------
        lens = jnp.where(live_mask, pos + n_tok, 0)
        batch = {"tokens": drafts, "lens": lens, "offsets": pos,
                 "scale_base": base}
        logits, caches = md.verify_paged(params, batch, caches, pt, cfg)
        idx = index[:, None] + jnp.arange(m, dtype=jnp.int32)[None]
        if hot:
            samples = sample_positions_keyed(logits, keys, idx, temps,
                                             top_ks, top_ps)
        else:
            samples = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        # -- 3. accept-prefix -----------------------------------------
        n_valid = accept_prefix(drafts, samples, n_tok)

        # -- 4. repair + token buffer ---------------------------------
        caches = repair_k_scale(caches, caches0, pos, base, n_tok, n_valid)
        dcaches = select_k_scale(dcaches, snaps, n_valid)
        last = jnp.take_along_axis(
            samples, jnp.clip(n_valid - 1, 0, m - 1)[:, None], axis=1)[:, 0]
        tok_buf = jnp.where(live_mask, last, tok_prev)
        packed = jnp.concatenate([samples, n_valid[:, None]], axis=1)
        return packed, tok_buf, caches, dcaches

    return fn
