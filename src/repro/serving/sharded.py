"""Tensor-parallel sharded serving: head-sharded paged pools and the
mesh-wide fused device step.

Partition the MEMORY, not the compute.  CAMformer's BA-CAM banks are
physically partitioned associative memories searched in parallel; the
serving analog shards the paged KV pool over a 1-axis ``("tp",)`` device
mesh (launch/mesh.py :func:`make_tp_mesh`) so each device holds a
kv-head slice of EVERY page and scores it locally:

  * every ``page_spec`` leaf whose logical axes name ``"kv_heads"``
    (dense ``k_pages``/``v_pages``, binary/camformer ``kp_pages``/
    ``k_scale``/``k_means``) gets one :class:`NamedSharding` placing
    ``"tp"`` on that axis — :func:`pool_partition_specs` derives the
    spec tree mechanically from the logical-axes tuples every backend
    already publishes, so new backends shard for free;
  * there is exactly ONE host page table and the host-pure ``Scheduler``
    is untouched — ``plan_tick()`` never reads device values, so the
    same plan drives a 1-device or an N-device step;
  * the whole tick — per-layer ``backend.paged_decode`` on local head
    slices, the paged cache write, and the vectorized keyed sampling —
    runs as ONE ``shard_map``-fused jitted step (:func:`shard_step`),
    with the sampled token ids still the only per-tick host readback.

Why all-gather of per-head attention outputs instead of a psum of
partial output projections: attention heads are independent, so gathering
the per-device head slices (models/attention.py) is pure concatenation —
no arithmetic — and every device reconstructs bit-identical full-head
activations.  The rest of the forward then runs replicated on each
device, producing identical logits and identical keyed samples, which is
what makes the tp>1 token streams bit-for-bit equal to the single-device
engine's.  A psum of per-shard partial ``wo`` projections would change
floating-point summation order and break token-for-token identity.

COW prefix forks and ``truncate_to`` rollback need no new code paths:
the fork copies pages along the PAGE axis (never the head axis), so the
same ``_copy_pool_page`` body runs shard_map-wrapped over the sharded
pools, and rollback is host page-table arithmetic only.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.utils import compat

__all__ = ["HEAD_AXIS", "TP_AXIS", "leaf_partition_spec",
           "pool_partition_specs", "shard_pools", "replicate", "shard_step"]

HEAD_AXIS = "kv_heads"  # the logical axis every page pool shards over
TP_AXIS = "tp"  # the mesh axis name (see launch/mesh.py make_tp_mesh)


def leaf_partition_spec(axes: Sequence[Optional[str]]) -> P:
    """PartitionSpec for one page_spec leaf: ``"tp"`` on the kv-head
    axis, every other dimension replicated."""
    return P(*(TP_AXIS if a == HEAD_AXIS else None for a in axes))


def _leaf_spec(name: str, sds: jax.ShapeDtypeStruct,
               axes: Tuple[Optional[str], ...], tp: int) -> P:
    if HEAD_AXIS not in axes:
        return P()
    dim = axes.index(HEAD_AXIS)
    if sds.shape[dim] % tp != 0:
        raise ValueError(
            f"page_spec leaf {name!r}: kv-head axis has extent "
            f"{sds.shape[dim]} (axis {dim} of shape {sds.shape}), which "
            f"does not divide over tp={tp}; pick a tp degree that divides "
            "n_kv_heads")
    return leaf_partition_spec(axes)


def pool_partition_specs(specs, tp: int):
    """Derive the PartitionSpec pytree for a page-pool tree from the
    ``(ShapeDtypeStruct, logical_axes)`` leaves of ``md.page_specs``.

    Mirrors the pool tree's structure exactly (uniform stacks: one dict
    with a leading "layers" axis; mixed ``layer_backends`` policies: a
    tuple of per-layer dicts) so the result drops straight into
    shard_map ``in_specs``/``out_specs`` and :func:`shard_pools`.
    Raises ``ValueError`` naming the offending leaf when any kv-head
    axis does not divide by ``tp``.
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")

    def one(layer, prefix=""):
        return {name: _leaf_spec(prefix + name, sds, axes, tp)
                for name, (sds, axes) in layer.items()}

    if isinstance(specs, tuple):  # mixed stack: per-layer trees
        return tuple(one(layer, f"layer{i}.")
                     for i, layer in enumerate(specs))
    return one(specs)


def shard_pools(pools, pspecs, mesh):
    """Place every pool leaf onto its head-sharded NamedSharding (the
    one-NamedSharding-per-page_spec-leaf allocation contract)."""

    def one(layer, layer_specs):
        return {k: jax.device_put(v, NamedSharding(mesh, layer_specs[k]))
                for k, v in layer.items()}

    if isinstance(pools, tuple):
        return tuple(one(lp, ls) for lp, ls in zip(pools, pspecs))
    return one(pools, pspecs)


def replicate(tree, mesh):
    """Replicate a pytree (params, token buffers) over the tp mesh so
    the fused step's non-pool inputs are already resident everywhere."""
    s = NamedSharding(mesh, P())
    return jax.tree.map(lambda a: jax.device_put(a, s), tree)


def shard_step(fn, mesh, in_specs, out_specs):
    """shard_map a fused engine step over the tp mesh.

    ``check_rep=False`` because the body's replication cannot be
    statically inferred through ``all_gather`` on jax 0.4.x (the outputs
    ARE replicated — the gather reconstructs identical full-head
    activations on every device; the identity tests assert it).  Newer
    jax versions that drop the kwarg fall through to the plain call.
    """
    try:
        return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=False)
    except TypeError:
        return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs)
