"""Token samplers for the serving engine.

``sample_step_keyed`` is the engine's per-tick entry point, fused INSIDE
the jitted device step: every row of the decode batch carries its own
temperature / top-k / top-p (the per-request ``SamplingParams``) plus its
own rng key and generated-token index, so the sampled ids are a pure
function of ``(request key, token index)`` — completely independent of
which engine tick, slot, or batch composition produced them.  That is
what makes the overlapped (dispatch-ahead) engine loop token-for-token
identical to the synchronous one, and preemption/resume regenerate the
same continuation.  ``sample_step`` is the single-key variant kept for
direct callers; ``apply_top_k`` / ``apply_top_p`` are the row-wise logit
filters, exposed separately so tests can pin them against a reference
implementation.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["greedy", "sample", "sample_step", "sample_step_keyed",
           "request_key", "apply_top_k", "apply_top_p"]

_MASKED = -1e9  # filtered logits (matches the vocab-padding mask value)


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def apply_top_k(logits: jax.Array, k) -> jax.Array:
    """Keep each row's k highest logits, mask the rest to -1e9.

    logits: (..., V); k: scalar or (...,) int32 — 0 disables the filter
    for that row.
    """
    v = logits.shape[-1]
    k = jnp.broadcast_to(jnp.asarray(k, jnp.int32), logits.shape[:-1])
    eff = jnp.where(k > 0, jnp.minimum(k, v), v)
    srt = jnp.sort(logits, axis=-1)[..., ::-1]  # descending
    kth = jnp.take_along_axis(srt, (eff - 1)[..., None], axis=-1)
    return jnp.where(logits < kth, _MASKED, logits)


def apply_top_p(logits: jax.Array, p) -> jax.Array:
    """Nucleus filter: keep each row's smallest high-probability set whose
    cumulative softmax mass reaches p, mask the rest to -1e9.

    logits: (..., V); p: scalar or (...,) float — the top-1 token is
    always kept; p >= 1.0 keeps every token with nonzero probability.
    Ties break by stable descending sort, matching a numpy
    ``argsort(-x, kind="stable")`` reference.
    """
    p = jnp.broadcast_to(jnp.asarray(p, jnp.float32),
                         logits.shape[:-1])[..., None]
    idx = jnp.argsort(-logits, axis=-1)  # stable descending
    sl = jnp.take_along_axis(logits, idx, axis=-1)
    sp = jax.nn.softmax(sl, axis=-1)
    cum = jnp.cumsum(sp, axis=-1)
    keep = (cum - sp) < p  # mass BEFORE this token is still short of p
    masked = jnp.where(keep, sl, _MASKED)
    inv = jnp.argsort(idx, axis=-1)  # inverse permutation
    return jnp.take_along_axis(masked, inv, axis=-1)


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 1.0,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """Single-policy sampling (python scalars). logits: (B, V)."""
    if temperature <= 0.0:
        return greedy(logits)
    z = logits / temperature
    if top_k:
        z = apply_top_k(z, top_k)
    if top_p < 1.0:
        z = apply_top_p(z, top_p)
    return jax.random.categorical(rng, z, axis=-1).astype(jnp.int32)


def sample_step(logits: jax.Array, rng: jax.Array, temperature, top_k,
                top_p) -> jax.Array:
    """Per-row sampling for one engine tick.

    logits: (B, V); temperature/top_k/top_p: (B,) arrays from each slot's
    ``SamplingParams``.  Rows with temperature <= 0 are greedy (their
    top-k/top-p values are ignored); the rest filter then draw
    categorically at their own temperature.
    """
    g = greedy(logits)
    t = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(t > 0, t, 1.0)[:, None]
    z = apply_top_p(apply_top_k(logits / safe_t, top_k), top_p)
    c = jax.random.categorical(rng, z, axis=-1).astype(jnp.int32)
    return jnp.where(t > 0, c, g)


def request_key(seed: int, rid: int) -> np.ndarray:
    """Deterministic per-request raw key data, derived on the HOST (no
    device work at admission time).  The engine folds the generated-token
    index in on-device, so sampling is a pure function of (seed, rid,
    index) — identical under sync/overlapped loops, slot reassignment,
    and preemption/resume."""
    return np.random.SeedSequence(entropy=(int(seed), int(rid))).generate_state(
        2, dtype=np.uint32)


def sample_step_keyed(logits, keys, index, temperature, top_k, top_p):
    """Per-row keyed sampling for one engine tick (fused into the step).

    logits: (B, V); keys: (B, 2) uint32 raw per-request key data;
    index: (B,) int32 generated-token index being sampled;
    temperature/top_k/top_p: (B,).  Rows with temperature <= 0 are greedy
    and never consume randomness; the rest filter then draw categorically
    from ``fold_in(key, index)`` — their draws do not depend on tick
    scheduling or on which other rows share the batch.
    """
    g = greedy(logits)
    t = jnp.asarray(temperature, jnp.float32)
    safe_t = jnp.where(t > 0, t, 1.0)[:, None]
    z = apply_top_p(apply_top_k(logits / safe_t, top_k), top_p)

    def draw(key, i, row):
        return jax.random.categorical(jax.random.fold_in(key, i), row)

    c = jax.vmap(draw)(keys, index.astype(jnp.int32), z).astype(jnp.int32)
    return jnp.where(t > 0, c, g)
