"""Token samplers for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["greedy", "sample"]


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    """Temperature + optional top-k sampling. logits: (B, V)."""
    if temperature <= 0.0:
        return greedy(logits)
    l = logits / temperature
    if top_k:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -1e9, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)
