"""Token samplers for the serving engine."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["greedy", "sample", "sample_batch"]


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits: jax.Array, rng: jax.Array, *, temperature: float = 1.0,
           top_k: int = 0) -> jax.Array:
    """Temperature + optional top-k sampling. logits: (B, V)."""
    if temperature <= 0.0:
        return greedy(logits)
    l = logits / temperature
    if top_k:
        kth = jax.lax.top_k(l, top_k)[0][..., -1:]
        l = jnp.where(l < kth, -1e9, l)
    return jax.random.categorical(rng, l, axis=-1).astype(jnp.int32)


def sample_batch(logits: jax.Array, rng: jax.Array,
                 temperatures: jax.Array) -> jax.Array:
    """Per-row temperature sampling for a batched prefill.

    logits: (B, V); temperatures: (B,) — rows with temperature <= 0 are
    greedy, the rest are categorical at their own temperature.
    """
    t = jnp.asarray(temperatures, jnp.float32)
    safe_t = jnp.where(t > 0, t, 1.0)[:, None]
    samp = jax.random.categorical(rng, logits / safe_t, axis=-1)
    return jnp.where(t > 0, samp.astype(jnp.int32), greedy(logits))
