"""Batched serving engine with continuous batching.

Slot-based scheduling over a fixed decode batch: finished sequences free
their slot, queued prompts are prefilled and spliced into the shared KV
cache, and every engine step decodes all active slots at their own
positions (ragged positions / kv lengths are native to the attention
masking).

ONE cache regime: every config serves from the paged KV cache
(serving/kv_cache.py).  The page *layout* is backend-polymorphic — each
layer's ``AttentionBackend`` (core/backend.py, resolved per layer via
``cfg.backend_for``) declares its pool leaves through the model's
``page_specs``:

  * dense / binary layers: bf16 ``k_pages`` / ``v_pages``;
  * camformer layers: bit-packed uint32 ``kp_pages`` (6.25% of bf16) +
    ``v_pages`` + the running ``k_scale`` temperature,

so a mixed ``layer_backends`` config keeps both layouts live in the same
pool, indirected by one shared page table.  A slot owns pages for the
tokens it actually needs (prompt + max_new), never a contiguous
``max_len`` reservation; admission prefills ALL newly admitted prompts in
one batched (and, with cfg.prefill_chunk, chunked) forward, and decode
runs every layer's ``backend.paged_decode`` each step.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import cast_params
from repro.models.transformer import dtype_of
from repro.serving import sampler as S
from repro.serving.kv_cache import TRASH_PAGE, PagedKVCache, pages_for

__all__ = ["Request", "ServeEngine"]

# Right-pad prompt batches to a multiple of this (bounds jit retraces).
PREFILL_BUCKET = 16


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0
    tokens: Optional[List[int]] = None  # generated


class ServeEngine:
    def __init__(self, md, cfg, params, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0,
                 page_size: int = 64, n_pages: Optional[int] = None):
        if md.page_specs is None:
            raise ValueError(
                f"{cfg.name!r} (family {cfg.family!r}) does not expose the "
                "paged serving interface (page_specs / prefill_paged / "
                "decode_paged) required by ServeEngine")
        self.md, self.cfg = md, cfg
        self.params = cast_params(params, dtype_of(cfg))
        self.max_batch, self.max_len = max_batch, max_len
        self.rng = jax.random.PRNGKey(seed)

        # prefill pads prompt batches to prefill_chunk multiples capped
        # at max_len; an indivisible max_len would silently skip the
        # chunked path (and its activation-memory bound) at the cap
        chunk = cfg.prefill_chunk
        if chunk and max_len % chunk != 0:
            raise ValueError(
                f"max_len={max_len} must be a multiple of "
                f"prefill_chunk={chunk} for paged serving")
        per_seq = pages_for(max_len, page_size)
        if n_pages is None:
            # Default: full residency (every slot can reach max_len).
            # Smaller pools trade capacity for admission backpressure.
            n_pages = 1 + max_batch * per_seq  # +1: trash page
        self.kv = PagedKVCache(n_pages, page_size, max_batch, per_seq)
        specs = md.page_specs(cfg, n_pages, page_size, max_batch)
        is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], jax.ShapeDtypeStruct))
        self.caches = jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype), specs,
            is_leaf=is_leaf)
        self._decode = jax.jit(
            lambda p, t, pos, kvl, c, pt: md.decode_paged(
                p, t, pos, kvl, c, pt, cfg))
        self._prefill = jax.jit(
            lambda p, b, c, pt: md.prefill_paged(p, b, c, pt, cfg))

        self.pos = np.zeros(max_batch, np.int32)  # next position per slot
        self.active: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.tokens = []
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new_tokens} > max_len "
                f"{self.max_len}")
        self.queue.append(req)

    def _next_rng(self):
        self.rng, sub = jax.random.split(self.rng)
        return sub

    # -- admission: batched (chunked) prefill into pages ---------------
    def _admit(self):
        admitted: List[tuple] = []
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue[0]
            need = len(req.prompt) + req.max_new_tokens
            if not self.kv.can_reserve(need, slot):
                break  # page pressure: keep FIFO order, retry next tick
            self.queue.pop(0)
            self.kv.reserve(slot, need)  # whole request up front: decode
            #                              can never hit pool-OOM mid-flight
            admitted.append((slot, req))
        if not admitted:
            if self.queue and all(r is None for r in self.active):
                req = self.queue[0]  # nothing in flight will ever free pages
                raise MemoryError(
                    f"request {req.rid} needs "
                    f"{pages_for(len(req.prompt) + req.max_new_tokens, self.kv.page_size)}"
                    f" pages; pool has {self.kv.n_pages - 1}")
            return
        bucket = self.cfg.prefill_chunk or PREFILL_BUCKET
        maxp = max(len(r.prompt) for _, r in admitted)
        s = min(-(-maxp // bucket) * bucket, self.max_len)
        tokens = np.zeros((self.max_batch, s), np.int32)
        lens = np.zeros(self.max_batch, np.int32)
        temps = np.zeros(self.max_batch, np.float32)
        for slot, req in admitted:
            tokens[slot, :len(req.prompt)] = req.prompt
            lens[slot] = len(req.prompt)
            temps[slot] = req.temperature
        # Non-admitted rows (inactive or mid-generation) are dummies: route
        # their padded-prompt writes to the trash page, NOT their own pages.
        pt = np.where(lens[:, None] > 0, self.kv.table, TRASH_PAGE)
        batch = {"tokens": jnp.asarray(tokens), "lens": jnp.asarray(lens)}
        logits, self.caches = self._prefill(
            self.params, batch, self.caches, jnp.asarray(pt))
        first = np.asarray(
            S.sample_batch(logits, self._next_rng(), jnp.asarray(temps)))
        for slot, req in admitted:
            req.tokens.append(int(first[slot]))
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)

    def _retire(self):
        """Move finished requests out of their slots, freeing pages."""
        for i, r in enumerate(self.active):
            if r is None:
                continue
            if (len(r.tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                self.done.append(r)
                self.active[i] = None
                self.kv.release(i)

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit new requests, decode all active slots."""
        self._admit()
        self._retire()  # e.g. max_new_tokens == 1: done at prefill
        if not any(r is not None for r in self.active):
            return False
        tokens = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                tokens[i] = r.tokens[-1]
        pos = jnp.asarray(self.pos)
        kv_len = jnp.asarray(self.pos + 1)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), pos, kv_len, self.caches,
            jnp.asarray(self.kv.table))
        nxt = S.greedy(logits)
        nxt_host = np.asarray(nxt)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.tokens.append(int(nxt_host[i]))
            self.pos[i] += 1
        self._retire()
        return True

    def run(self):
        while self.queue or any(r is not None for r in self.active):
            self.step()
        return self.done
