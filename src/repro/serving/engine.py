"""Production serving engine: continuous batching with an explicit
request lifecycle, streamed outputs, per-request sampling, priority
preemption, and copy-on-write prefix sharing.

Architecture (one engine tick = ``step()``):

  1. ``schedule()`` — ADMISSION POLICY, host-only.  Picks queued requests
     (highest priority first, FIFO within a class), hash-matches their
     prompts against the paged cache's prefix registry (shared system
     prompts attach already-prefilled pages read-only; a mid-page match
     forks its boundary page copy-on-write), reserves pages for
     ``prompt + max_new`` up front, and — under page pressure — preempts
     the lowest-priority decoding slot back to the queue (pages released,
     generated tokens kept; resume re-prefills prompt+generated).
  2. ``prefill(admissions)`` — one batched (and, with
     ``cfg.prefill_chunk``, chunked) forward over every admitted suffix.
     Requests with a matched prefix prefill ONLY the unmatched tokens at
     their true positions (``offsets``); the first generated token is
     sampled per-request (temperature / top-k / top-p).
  3. decode tick — every active slot advances one token through its
     layer's ``backend.paged_decode``, sampled with its own
     ``SamplingParams``; finished/stopped requests retire and free pages.

Streaming: every generated token is surfaced as a ``RequestOutput`` from
``step()`` / the ``engine.stream()`` iterator, and through each request's
``on_token`` callback.  ``cancel(rid)`` removes a queued or running
request immediately and frees its pages.

ONE cache regime: every config serves from the paged KV cache
(serving/kv_cache.py).  The page *layout* is backend-polymorphic — each
layer's ``AttentionBackend`` (core/backend.py, resolved per layer via
``cfg.backend_for``) declares its pool leaves through the model's
``page_specs``: dense/binary layers use bf16 ``k_pages``/``v_pages``,
camformer layers bit-packed uint32 ``kp_pages`` + ``v_pages`` +
``k_scale``, all indirected by one shared page table.  COW forks copy a
physical page across every layer's pools in one jitted device op.
"""

from __future__ import annotations

from typing import Iterator, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import cast_params
from repro.models.transformer import dtype_of
from repro.serving import sampler as S
from repro.serving.kv_cache import (NO_MATCH, TRASH_PAGE, PagedKVCache,
                                    pages_for)
from repro.serving.request import (Request, RequestOutput, RequestState,
                                   SamplingParams)

__all__ = ["Request", "SamplingParams", "RequestState", "RequestOutput",
           "Admission", "ServeEngine"]

# Right-pad prompt batches to a multiple of this (bounds jit retraces).
PREFILL_BUCKET = 16


class Admission(NamedTuple):
    """One scheduling decision: where a request lands and what it shares."""

    slot: int
    req: Request
    resume_from: int  # generated tokens carried across a preemption
    matched: int  # prefix tokens served from shared pages (0 = none)
    forks: Tuple[Tuple[int, int], ...]  # (src, dst) COW page copies


def _copy_pool_page(caches, src, dst):
    """Copy physical page ``src`` -> ``dst`` across every layer's page
    pools (the device half of a COW fork).  Page leaves are recognized by
    the ``*_pages`` naming contract of ``AttentionBackend.page_spec``;
    per-slot leaves (``k_scale``) are untouched."""

    def one(layer, axis):
        out = {}
        for name, arr in layer.items():
            if name.endswith("_pages"):
                sl = (slice(None),) * axis
                out[name] = arr.at[sl + (dst,)].set(arr[sl + (src,)])
            else:
                out[name] = arr
        return out

    if isinstance(caches, tuple):  # mixed layer_backends: per-layer trees
        return tuple(one(layer, 0) for layer in caches)
    return one(caches, 1)  # uniform: leading `layers` axis


class ServeEngine:
    def __init__(self, md, cfg, params, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0,
                 page_size: int = 64, n_pages: Optional[int] = None,
                 prefix_sharing: bool = True):
        if md.page_specs is None:
            raise ValueError(
                f"{cfg.name!r} (family {cfg.family!r}) does not expose the "
                "paged serving interface (page_specs / prefill_paged / "
                "decode_paged) required by ServeEngine")
        self.md, self.cfg = md, cfg
        self.params = cast_params(params, dtype_of(cfg))
        self.max_batch, self.max_len = max_batch, max_len
        self.rng = jax.random.PRNGKey(seed)
        self.prefix_sharing = prefix_sharing

        # prefill pads prompt batches to prefill_chunk multiples capped
        # at max_len; an indivisible max_len would silently skip the
        # chunked path (and its activation-memory bound) at the cap
        chunk = cfg.prefill_chunk
        if chunk and max_len % chunk != 0:
            raise ValueError(
                f"max_len={max_len} must be a multiple of "
                f"prefill_chunk={chunk} for paged serving")
        per_seq = pages_for(max_len, page_size)
        if n_pages is None:
            # Default: full residency (every slot can reach max_len).
            # Smaller pools trade capacity for admission backpressure.
            n_pages = 1 + max_batch * per_seq  # +1: trash page
        self.kv = PagedKVCache(n_pages, page_size, max_batch, per_seq)
        specs = md.page_specs(cfg, n_pages, page_size, max_batch)
        is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], jax.ShapeDtypeStruct))
        self.caches = jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype), specs,
            is_leaf=is_leaf)
        self._decode = jax.jit(
            lambda p, t, pos, kvl, c, pt, base: md.decode_paged(
                p, t, pos, kvl, c, pt, cfg, base=base))
        self._prefill = jax.jit(
            lambda p, b, c, pt: md.prefill_paged(p, b, c, pt, cfg))
        self._fork = jax.jit(_copy_pool_page)

        self.pos = np.zeros(max_batch, np.int32)  # next position per slot
        self.base = np.zeros(max_batch, np.int32)  # prefix offset per slot
        self.active: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.peak_pages = 0  # high-water mark of unique resident pages
        self._next_rid = 0
        self._arrival = 0  # FIFO tiebreak within a priority class
        self._admissions = 0  # preemption tiebreak (evict newest first)

    # ------------------------------------------------------------------
    # submission / cancellation
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Queue a request; returns its rid (auto-assigned when None)."""
        if req.rid is None:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        if not req.prompt:
            raise ValueError(f"request {req.rid}: empty prompt")
        need = len(req.prompt) + req.sampling.max_new
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new {need} > max_len "
                f"{self.max_len}")
        req.state = RequestState.QUEUED
        req.tokens = []
        req.finish_reason = None
        req._seq = self._arrival  # FIFO order, kept across preemption
        self._arrival += 1
        self.queue.append(req)
        return req.rid

    def cancel(self, rid: int) -> Optional[RequestOutput]:
        """Terminate a queued or running request NOW; running requests
        free their pages immediately.  Returns the final output record,
        or None if rid is not live."""
        for qi, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(qi)
                return self._finish(r, "cancelled")
        for slot, r in enumerate(self.active):
            if r is not None and r.rid == rid:
                self.kv.release(slot)
                self.active[slot] = None
                return self._finish(r, "cancelled")
        return None

    def _finish(self, req: Request, reason: str) -> RequestOutput:
        req.state = (RequestState.CANCELLED if reason == "cancelled"
                     else RequestState.FINISHED)
        req.finish_reason = reason
        self.done.append(req)
        out = RequestOutput(
            rid=req.rid, token=None, index=len(req.tokens), state=req.state,
            finished=True, finish_reason=reason, tokens=tuple(req.tokens))
        if req.on_token:
            req.on_token(out)
        return out

    # ------------------------------------------------------------------
    # scheduling (admission policy — no model computation)
    # ------------------------------------------------------------------
    def _next_queued_index(self) -> int:
        return min(range(len(self.queue)),
                   key=lambda i: (-self.queue[i].priority,
                                  self.queue[i]._seq))

    def _pick_victim(self, priority: int) -> Optional[int]:
        """Lowest-priority active slot strictly below `priority`; among
        equals, the most recently admitted (least prefill to redo... the
        newest has generated the least)."""
        best = None
        for slot, r in enumerate(self.active):
            # only DECODING slots are evictable: a PREFILLING slot was
            # admitted this very tick and its forward has not run yet
            if (r is None or r.state is not RequestState.DECODING
                    or r.priority >= priority):
                continue
            key = (r.priority, -r._admit_seq)
            if best is None or key < best[0]:
                best = (key, slot)
        return None if best is None else best[1]

    def _preempt(self, slot: int) -> None:
        req = self.active[slot]
        self.kv.release(slot)  # sharers keep refcounted pages alive
        self.active[slot] = None
        req.state = RequestState.QUEUED  # tokens kept: resume re-prefills
        self.queue.append(req)  # _seq unchanged: keeps its FIFO standing

    def schedule(self) -> List[Admission]:
        """Admission policy: fill free slots from the queue, matching
        shared prefixes and preempting lower-priority decoders under page
        pressure.  Mutates allocator state (reservations, refcounts, fork
        page ids) but runs NO model computation — ``prefill`` consumes
        the returned admissions."""
        admitted: List[Admission] = []
        while self.queue:
            qi = self._next_queued_index()
            req = self.queue[qi]
            effective = req.prompt + req.tokens  # resume covers generated
            need = len(req.prompt) + req.sampling.max_new
            match = (self.kv.match_prefix(effective)
                     if self.prefix_sharing else NO_MATCH)
            if match.defer:
                break  # prefix pages materialize this tick; retry next
            slot = next(
                (i for i, r in enumerate(self.active) if r is None), None)
            if slot is None or not self.kv.can_reserve(
                    need, slot, n_shared=len(match.shared)):
                victim = self._pick_victim(req.priority)
                if victim is None:
                    break  # page pressure: wait for retirements
                self._preempt(victim)
                continue  # re-match: the release may have dropped pages
            self.queue.pop(qi)
            forks = self.kv.reserve_shared(slot, match, need)
            if self.prefix_sharing:
                self.kv.register_prefix(slot, effective)
            req.state = RequestState.PREFILLING
            req.prefix_matched = match.matched
            req._admit_seq = self._admissions
            self._admissions += 1
            self.active[slot] = req  # slot is taken from this point on
            admitted.append(Admission(
                slot, req, len(req.tokens), match.matched, tuple(forks)))
        if not admitted and self.queue and all(
                r is None for r in self.active):
            req = self.queue[self._next_queued_index()]
            raise MemoryError(
                f"request {req.rid} needs "
                f"{pages_for(len(req.prompt) + req.sampling.max_new, self.kv.page_size)}"
                f" pages; pool has {self.kv.n_pages - 1}")
        self.peak_pages = max(self.peak_pages, self.kv.used_pages)
        return admitted

    # ------------------------------------------------------------------
    # prefill (batched, chunked, prefix-skipping)
    # ------------------------------------------------------------------
    def _next_rng(self):
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def _sample(self, logits, per_slot):
        """Per-request sampling for one tick.  The all-greedy case (the
        default policy) short-circuits to a single argmax — no sorts, no
        categorical, no rng split on the decode hot path."""
        if all(sp.temperature <= 0.0 for _, sp in per_slot):
            return np.asarray(S.greedy(logits))
        temps = np.zeros(self.max_batch, np.float32)
        top_ks = np.zeros(self.max_batch, np.int32)
        top_ps = np.ones(self.max_batch, np.float32)
        for slot, sp in per_slot:
            temps[slot] = sp.temperature
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
        return np.asarray(S.sample_step(
            logits, self._next_rng(), jnp.asarray(temps),
            jnp.asarray(top_ks), jnp.asarray(top_ps)))

    def prefill(self, admitted: List[Admission]) -> List[RequestOutput]:
        """Run the batched (chunked) prefill for this tick's admissions:
        COW fork copies first, then one forward over every admitted
        suffix at its true positions, then per-request first-token
        sampling."""
        events: List[RequestOutput] = []
        if not admitted:
            return events
        for adm in admitted:  # copy shared boundary pages BEFORE writes
            for src, dst in adm.forks:
                self.caches = self._fork(
                    self.caches, jnp.int32(src), jnp.int32(dst))
        bucket = self.cfg.prefill_chunk or PREFILL_BUCKET
        suffixes = {adm.slot: (adm.req.prompt + adm.req.tokens)[adm.matched:]
                    for adm in admitted}
        maxs = max(len(s) for s in suffixes.values())
        s = min(-(-maxs // bucket) * bucket, self.max_len)
        tokens = np.zeros((self.max_batch, s), np.int32)
        lens = np.zeros(self.max_batch, np.int32)
        offsets = np.zeros(self.max_batch, np.int32)
        for adm in admitted:
            suf = suffixes[adm.slot]
            tokens[adm.slot, :len(suf)] = suf
            lens[adm.slot] = adm.matched + len(suf)  # TOTAL valid length
            offsets[adm.slot] = adm.matched
        # Non-admitted rows (inactive or mid-generation) are dummies: route
        # their padded-prompt writes to the trash page, NOT their own pages.
        pt = np.where(lens[:, None] > 0, self.kv.table, TRASH_PAGE)
        batch = {"tokens": jnp.asarray(tokens), "lens": jnp.asarray(lens),
                 "offsets": jnp.asarray(offsets)}
        logits, self.caches = self._prefill(
            self.params, batch, self.caches, jnp.asarray(pt))
        self.kv.commit_prefixes()  # registered prefixes now materialized
        first = self._sample(
            logits, [(adm.slot, adm.req.sampling) for adm in admitted])
        for adm in admitted:
            req = adm.req
            self.active[adm.slot] = req
            self.pos[adm.slot] = lens[adm.slot]
            self.base[adm.slot] = adm.matched
            req.state = RequestState.DECODING
            events.append(self._append(adm.slot, req, int(first[adm.slot])))
        return events

    def _append(self, slot: int, req: Request, token: int) -> RequestOutput:
        """Record one generated token, detect finish, emit the output."""
        req.tokens.append(token)
        reason = None
        if token in req.sampling.stop:
            reason = "stop"
        elif (len(req.tokens) >= req.sampling.max_new
              or self.pos[slot] >= self.max_len - 1):
            reason = "length"
        if reason is not None:
            req.state = RequestState.FINISHED
            req.finish_reason = reason
        out = RequestOutput(
            rid=req.rid, token=token, index=len(req.tokens),
            state=req.state, finished=reason is not None,
            finish_reason=reason, tokens=tuple(req.tokens))
        if req.on_token:
            req.on_token(out)
        return out

    def _retire(self) -> None:
        """Free the slots of requests that finished this tick."""
        for slot, r in enumerate(self.active):
            if r is not None and r.state.is_terminal:
                self.done.append(r)
                self.active[slot] = None
                self.kv.release(slot)

    # ------------------------------------------------------------------
    # the engine tick
    # ------------------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """One engine tick: schedule + prefill admissions, then decode
        every active slot one token.  Returns this tick's streamed
        outputs (empty when the engine is idle)."""
        events = self.prefill(self.schedule())
        self._retire()  # e.g. max_new == 1: finished at prefill
        live = [(i, r) for i, r in enumerate(self.active) if r is not None]
        if not live:
            return events
        tokens = np.zeros(self.max_batch, np.int32)
        for i, r in live:
            tokens[i] = r.tokens[-1]
        pos = jnp.asarray(self.pos)
        kv_len = jnp.asarray(self.pos + 1)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), pos, kv_len, self.caches,
            jnp.asarray(self.kv.table), jnp.asarray(self.base))
        nxt = self._sample(logits, [(i, r.sampling) for i, r in live])
        for i, r in live:
            self.pos[i] += 1
            events.append(self._append(i, r, int(nxt[i])))
        self._retire()
        return events

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    def stream(self, *requests: Request) -> Iterator[RequestOutput]:
        """Submit `requests` (if given) and drive the engine, yielding
        each generated token as a RequestOutput until the pool drains.
        Token-for-token identical to ``run()`` — same ticks, same rng."""
        for r in requests:
            self.submit(r)
        while self.has_work:
            yield from self.step()

    def run(self) -> List[Request]:
        """Drain the engine; returns completed requests in finish order."""
        for _ in self.stream():
            pass
        return self.done
