"""Overlapped serving engine: host-plan / device-step split with
dispatch-ahead decode and continuous chunked-prefill batching.

Three-part architecture (see also serving/scheduler.py):

  1. ``Scheduler`` — host-pure admission, preemption, finish detection,
     and page planning.  ``plan_tick()`` emits a ``TickPlan`` computed
     entirely from host state: which requests admit (and which COW pages
     fork), one prompt chunk per PREFILLING slot, one decode row per
     DECODING slot, plus the per-slot sampling-parameter / rng-key
     arrays.

  2. the fused device step — per-layer ``backend.paged_decode`` dispatch
     + paged cache write + vectorized keyed sampling run inside ONE jit
     per tick, so the sampled token ids (one ``(B,)`` int32 array) are
     the only host<->device readback of a decode tick.  The step's input
     tokens come from the ON-DEVICE token buffer of the previous tick
     (double-buffered), merged with this tick's prefill first-token
     samples — never from a host round-trip.

  3. the loop — ``mode="sync"`` reads each tick's tokens immediately
     (plan -> dispatch -> read); ``mode="overlap"`` dispatches tick
     ``t+1`` from the not-yet-read token buffer of tick ``t``, then
     reads tick ``t`` while ``t+1`` executes, overlapping host
     scheduling/bookkeeping with the device forward.  Host visibility of
     token VALUES is deferred one tick; everything value-independent
     (positions, page budgets, max_new finishes) is planned exactly as
     in sync mode, so the two modes are token-for-token identical (same
     per-request rng: sampling is keyed by ``(seed, rid, index)``).
     A stop-token finish is value-dependent, so the overlapped loop runs
     at most one extra "zombie" tick for that slot — its writes land in
     pages the slot still owns and its sampled token is discarded at
     ingest, never surfaced.

Continuous batching: with ``prefill_slice=N`` a joining request prefills
in N-token (page-sized) chunks across ticks while existing slots keep
decoding, instead of a stop-the-world whole-prompt prefill
(``prefill_slice=None``, the default, preserves the classic regime).

ONE cache regime: every config serves from the paged KV cache
(serving/kv_cache.py) with backend-polymorphic page layouts, COW prefix
sharing, and LRU prefix retention; see that module.
"""

from __future__ import annotations

import logging
import time
from typing import Iterator, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.launch.mesh import make_tp_mesh
from repro.launch.steps import cast_params
from repro.models.transformer import dtype_of
from repro.serving import sampler as S
from repro.serving import sharded
from repro.serving import speculate
from repro.serving.faults import NO_FAULTS, FaultPlan
from repro.serving.kv_cache import PagedKVCache, pages_for
from repro.serving.request import (Request, RequestOutput, RequestState,
                                   SamplingParams)
from repro.serving.scheduler import Admission, Emit, Scheduler, TickPlan

__all__ = ["Request", "SamplingParams", "RequestState", "RequestOutput",
           "Admission", "Scheduler", "ServeEngine"]

log = logging.getLogger("repro.serving.engine")

# Right-pad prompt batches to a multiple of this (bounds jit retraces).
PREFILL_BUCKET = 16


def _copy_pool_page(caches, src, dst):
    """Copy physical page ``src`` -> ``dst`` across every layer's page
    pools (the device half of a COW fork).  Page leaves are recognized by
    the ``*_pages`` naming contract of ``AttentionBackend.page_spec``;
    per-slot leaves (``k_scale``) are untouched."""

    def one(layer, axis):
        out = {}
        for name, arr in layer.items():
            if name.endswith("_pages"):
                sl = (slice(None),) * axis
                out[name] = arr.at[sl + (dst,)].set(arr[sl + (src,)])
            else:
                out[name] = arr
        return out

    if isinstance(caches, tuple):  # mixed layer_backends: per-layer trees
        return tuple(one(layer, 0) for layer in caches)
    return one(caches, 1)  # uniform: leading `layers` axis


class _InFlight(NamedTuple):
    """Device handles of one dispatched tick, read back one tick later
    (overlap) or immediately (sync)."""

    prefill_tok: Optional[jax.Array]  # (B,) sampled first tokens
    prefill_emit: Tuple[Emit, ...]
    decode_tok: Optional[jax.Array]  # (B,) sampled decode tokens, or the
    #                                   (B, m+1) spec pack (samples ++ n_valid)
    decode_emit: Tuple[Emit, ...]
    spec: bool = False  # decode_tok is a speculative multi-token pack

    @property
    def empty(self) -> bool:
        return not (self.prefill_emit or self.decode_emit)


class ServeEngine:
    def __init__(self, md, cfg, params, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0,
                 page_size: int = 64, n_pages: Optional[int] = None,
                 prefix_sharing: bool = True, mode: str = "overlap",
                 prefill_slice: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 prefill_impl: Optional[str] = None,
                 spec_k: Optional[int] = None,
                 spec_backend: Optional[str] = None,
                 tp: int = 1, max_queue: Optional[int] = None,
                 faults: Optional[FaultPlan] = None):
        if paged_impl is not None:
            # per-engine override of the decode realization: "fused"
            # (Pallas paged flash/CAM kernels, the default) vs "gather"
            # (the XLA page-gather reference) — rides on cfg so every
            # layer's backend.paged_decode inside the fused device step
            # sees it; ModelConfig validates the value
            cfg = cfg.replace(paged_impl=paged_impl)
        if prefill_impl is not None:
            # per-engine override of the Sq>1 chunk realization
            # (chunked prefill / speculative verify): "auto" follows
            # paged_impl, "fused"/"gather" pin it independently
            cfg = cfg.replace(prefill_impl=prefill_impl)
        if spec_k is not None or spec_backend is not None:
            # per-engine override of the speculative-decoding policy —
            # rides on cfg like paged_impl (ModelConfig validates)
            cfg = cfg.replace(
                spec_k=cfg.spec_k if spec_k is None else spec_k,
                spec_backend=(cfg.spec_backend if spec_backend is None
                              else spec_backend))
        if md.page_specs is None:
            raise ValueError(
                f"{cfg.name!r} (family {cfg.family!r}) does not expose the "
                "paged serving interface (page_specs / prefill_paged / "
                "decode_paged) required by ServeEngine")
        if mode not in ("sync", "overlap"):
            raise ValueError(f"mode must be 'sync' or 'overlap', got {mode!r}")
        if tp < 1:
            raise ValueError(f"tp must be >= 1, got {tp}")
        self.tp = tp
        self.mesh = None
        self._pool_pspecs = self._draft_pspecs = None
        if tp > 1:
            # tensor-parallel sharded serving (serving/sharded.py): the
            # page pools head-shard over a 1-axis tp mesh and every
            # fused step runs shard_map-wrapped.  tp == 1 takes none of
            # these branches — it IS today's single-device engine, same
            # code path (self.mesh stays None; the identity tests assert
            # both).
            if jax.device_count() < tp:
                raise ValueError(
                    f"tp={tp} needs at least {tp} devices, have "
                    f"{jax.device_count()} (CPU: set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={tp})")
            self.mesh = make_tp_mesh(tp)
        self.md, self.cfg = md, cfg
        self.params = cast_params(params, dtype_of(cfg))
        self.max_batch, self.max_len = max_batch, max_len
        self.mode = mode

        # prefill pads prompt batches to prefill_chunk multiples capped
        # at max_len; an indivisible max_len would silently skip the
        # chunked path (and its activation-memory bound) at the cap
        chunk = cfg.prefill_chunk
        if chunk and max_len % chunk != 0:
            raise ValueError(
                f"max_len={max_len} must be a multiple of "
                f"prefill_chunk={chunk} for paged serving")
        per_seq = pages_for(max_len, page_size)
        if n_pages is None:
            # Default: full residency (every slot can reach max_len).
            # Smaller pools trade capacity for admission backpressure.
            n_pages = 1 + max_batch * per_seq  # +1: trash page
        # chaos harness: no-op-by-default fault hooks (serving/faults.py),
        # threaded through the allocator and consulted once per tick
        self.faults = NO_FAULTS if faults is None else faults
        self.kv = PagedKVCache(n_pages, page_size, max_batch, per_seq,
                               faults=self.faults)
        self.spec_k = cfg.spec_k
        self.sched = Scheduler(
            self.kv, max_batch=max_batch, max_len=max_len, seed=seed,
            prefix_sharing=prefix_sharing, prefill_slice=prefill_slice,
            prefill_bucket=chunk or PREFILL_BUCKET, spec_k=self.spec_k,
            max_queue=max_queue)
        specs = md.page_specs(cfg, n_pages, page_size, max_batch)
        is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], jax.ShapeDtypeStruct))
        zeros = lambda t: jnp.zeros(t[0].shape, t[0].dtype)
        if tp > 1:  # validate head divisibility BEFORE allocating pools
            self._pool_pspecs = sharded.pool_partition_specs(specs, tp)
        self.caches = jax.tree.map(zeros, specs, is_leaf=is_leaf)
        if tp > 1:  # one NamedSharding per page_spec leaf
            self.caches = sharded.shard_pools(self.caches,
                                              self._pool_pspecs, self.mesh)
        # speculative decoding: the drafter stack (same weights, every
        # layer forced to cfg.spec_backend) keeps its OWN page pools on
        # the SAME page table, so admission / COW forks / rollback are
        # planned once for both (serving/speculate.py)
        self.draft_caches = None
        self._draft_cfg = None
        if self.spec_k:
            if md.verify_paged is None:
                raise ValueError(
                    f"{cfg.name!r} does not expose verify_paged "
                    "(all-position logits), required for spec_k > 0")
            self._draft_cfg = speculate.draft_config(cfg)
            dspecs = md.page_specs(self._draft_cfg, n_pages, page_size,
                                   max_batch)
            if tp > 1:
                self._draft_pspecs = sharded.pool_partition_specs(dspecs, tp)
            self.draft_caches = jax.tree.map(zeros, dspecs, is_leaf=is_leaf)
            if tp > 1:
                self.draft_caches = sharded.shard_pools(
                    self.draft_caches, self._draft_pspecs, self.mesh)
        self._prefill_jits = {}  # hot -> jitted fused prefill-chunk step
        self._decode_jits = {}  # hot -> jitted fused decode step
        self._spec_jits = {}  # hot -> jitted fused draft+verify step
        if tp == 1:
            self._fork = jax.jit(_copy_pool_page)
            self._fork_draft = self._fork
        else:
            # the COW fork copies along the PAGE axis, never the head
            # axis, so the same body runs on the local pool shards; the
            # target and drafter trees need separate wraps only because
            # their spec trees differ (e.g. mixed target, uniform draft)
            R = PartitionSpec()
            self._fork = jax.jit(sharded.shard_step(
                _copy_pool_page, self.mesh, (self._pool_pspecs, R, R),
                self._pool_pspecs))
            self._fork_draft = None if self._draft_pspecs is None else (
                jax.jit(sharded.shard_step(
                    _copy_pool_page, self.mesh, (self._draft_pspecs, R, R),
                    self._draft_pspecs)))
        # double-buffered on-device token state: the decode step's input
        # tokens are the previous step's output, never a host round-trip
        self._tok_buf = jnp.zeros((max_batch,), jnp.int32)
        self._zero_tok = jnp.zeros((max_batch,), jnp.int32)
        if tp > 1:
            # params and token state are replicated residents of the
            # mesh; only the page pools shard
            self.params = sharded.replicate(self.params, self.mesh)
            self._tok_buf = sharded.replicate(self._tok_buf, self.mesh)
            self._zero_tok = sharded.replicate(self._zero_tok, self.mesh)

        # overlap-mode dispatch-ahead state: the tick whose tokens have
        # been dispatched but not read yet (None in sync mode / idle)
        self._pending: Optional[_InFlight] = None

        # crash containment: emits of the tick currently being read, with
        # settled entries removed, so a readback that dies midway can
        # drop exactly the remainder (see _collect / _fail_tick)
        self._settling: List[Emit] = []

        # instrumentation (benchmarks / the single-readback invariant)
        self.readbacks = 0  # device->host transfers (token id arrays)
        self.blocked_s = 0.0  # host time spent blocked on readbacks
        self.ticks = 0  # decode steps dispatched
        self.tick_errors = 0  # device ticks that failed and were contained
        self.last_error: Optional[str] = None  # most recent contained error

    # ------------------------------------------------------------------
    # scheduler delegation (host state lives on self.sched)
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> int:
        return self.sched.submit(req)

    def cancel(self, rid: int) -> Optional[RequestOutput]:
        return self.sched.cancel(rid)

    def schedule(self) -> List[Admission]:
        """Admission policy alone (no model computation) — see
        ``Scheduler.admit``."""
        return self.sched.admit()

    @property
    def queue(self) -> List[Request]:
        return self.sched.queue

    @property
    def active(self) -> List[Optional[Request]]:
        return self.sched.active

    @property
    def done(self) -> List[Request]:
        return self.sched.done

    @property
    def peak_pages(self) -> int:
        return self.sched.peak_pages

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    @property
    def has_pending(self) -> bool:
        """True while a dispatched-ahead tick's tokens are still unread
        (``mode="overlap"``); a driver loop must keep polling until both
        ``has_work`` and ``has_pending`` clear."""
        return self._pending is not None

    @property
    def preemptions(self) -> int:
        return self.sched.preemptions

    @property
    def prefill_tokens(self) -> int:
        """Prompt tokens materialized through chunked-prefill steps."""
        return self.sched.prefill_tokens

    @property
    def prefill_ticks(self) -> int:
        """Engine ticks that carried a prefill chunk (TTFT attribution:
        flat chunk counters under a TTFT regression point at the decode
        or queueing path, rising ones at the prefill path)."""
        return self.sched.prefill_ticks

    @property
    def spec_proposed(self) -> int:
        """Draft tokens proposed by the speculative drafter stack."""
        return self.sched.spec_proposed

    @property
    def spec_accepted(self) -> int:
        """Proposed draft tokens the target stack verified and kept."""
        return self.sched.spec_accepted

    @property
    def spec_acceptance(self) -> float:
        """spec_accepted / spec_proposed (0.0 before any speculation)."""
        return self.sched.spec_acceptance

    # ------------------------------------------------------------------
    # the fused device step (everything per tick inside one jit)
    # ------------------------------------------------------------------
    def _shardify(self, fn, n_before, n_after, caches_out_prefix=1):
        """tp > 1: shard_map the fused step over the tp mesh before jit.

        Every step fn takes (``n_before`` replicated args, the target
        pool tree[, the drafter pool tree], ``n_after`` replicated args)
        and returns (``caches_out_prefix`` replicated outputs, then the
        pool tree(s) in the same order).  The pool trees are the ONLY
        sharded operands — the model compute is replicated per device
        except the head-sliced paged attention (models/attention.py),
        whose all_gather restores replication, so replicated out_specs
        for the sampled tokens are exact.
        """
        R = PartitionSpec()
        pools = ((self._pool_pspecs,) if self._draft_pspecs is None
                 else (self._pool_pspecs, self._draft_pspecs))
        in_specs = (R,) * n_before + pools + (R,) * n_after
        out_specs = (R,) * caches_out_prefix + pools
        return sharded.shard_step(fn, self.mesh, in_specs, out_specs)

    def _prefill_jit(self, hot: bool):
        if hot not in self._prefill_jits:
            md, cfg = self.md, self.cfg
            if self.spec_k:
                fn = speculate.build_spec_prefill(md, cfg, self._draft_cfg,
                                                  hot)
            else:

                def fn(params, tokens, lens, offsets, scale_base, caches,
                       pt, keys, index, temps, top_ks, top_ps):
                    batch = {"tokens": tokens, "lens": lens,
                             "offsets": offsets, "scale_base": scale_base}
                    logits, caches = md.prefill_paged(params, batch, caches,
                                                      pt, cfg)
                    if hot:
                        first = S.sample_step_keyed(logits, keys, index,
                                                    temps, top_ks, top_ps)
                    else:
                        first = S.greedy(logits)
                    return first, caches

            if self.tp > 1:
                # (params..scale_base | pools | pt..top_ps) -> (first, pools)
                fn = self._shardify(fn, 5, 6)
            self._prefill_jits[hot] = jax.jit(fn)
        return self._prefill_jits[hot]

    def _spec_jit(self, hot: bool):
        if hot not in self._spec_jits:
            fn = speculate.build_spec_step(
                self.md, self.cfg, self._draft_cfg, self.spec_k + 1, hot)
            if self.tp > 1:
                # (params..n_tok | pools | pt..top_ps)
                #   -> (packed, tok_buf, pools)
                fn = self._shardify(fn, 7, 7, caches_out_prefix=2)
            self._spec_jits[hot] = jax.jit(fn)
        return self._spec_jits[hot]

    def _decode_jit(self, hot: bool):
        if hot not in self._decode_jits:
            md, cfg = self.md, self.cfg

            def fn(params, tok_prev, fresh, fresh_mask, live_mask, pos,
                   kv_len, caches, pt, base, keys, index, temps, top_ks,
                   top_ps):
                # merge the double-buffered token state on-device: rows
                # that finished prefill THIS tick take their freshly
                # sampled first token, continuing rows take the previous
                # step's output, inert rows are pinned to 0 (keeps the
                # batch contents identical to the sync loop's)
                tokens = jnp.where(live_mask,
                                   jnp.where(fresh_mask, fresh, tok_prev), 0)
                logits, caches = md.decode_paged(
                    params, tokens, pos, kv_len, caches, pt, cfg, base=base)
                if hot:
                    nxt = S.sample_step_keyed(logits, keys, index, temps,
                                              top_ks, top_ps)
                else:
                    nxt = S.greedy(logits)
                return nxt, caches

            if self.tp > 1:
                # (params..kv_len | pools | pt..top_ps) -> (nxt, pools)
                fn = self._shardify(fn, 7, 7)
            self._decode_jits[hot] = jax.jit(fn)
        return self._decode_jits[hot]

    def _dispatch(self, plan: TickPlan) -> _InFlight:
        """Enqueue one tick's device work; returns unread token handles."""
        self.faults.raise_if("step.error")  # chaos: the fused step dies
        for src, dst in plan.forks:  # COW copies BEFORE any write
            self.caches = self._fork(
                self.caches, jnp.int32(src), jnp.int32(dst))
            if self.draft_caches is not None:  # drafter aliases the same
                self.draft_caches = self._fork_draft(  # page ids: fork both
                    self.draft_caches, jnp.int32(src), jnp.int32(dst))
        keys = jnp.asarray(plan.keys)
        temps = jnp.asarray(plan.temps)
        top_ks = jnp.asarray(plan.top_ks)
        top_ps = jnp.asarray(plan.top_ps)
        prefill_tok = None
        fresh, fresh_mask = self._zero_tok, None
        pf = plan.prefill
        if pf is not None:
            if self.spec_k:
                first, self.caches, self.draft_caches = self._prefill_jit(
                    pf.hot)(
                    self.params, jnp.asarray(pf.tokens),
                    jnp.asarray(pf.lens), jnp.asarray(pf.offsets),
                    jnp.asarray(pf.scale_base), self.caches,
                    self.draft_caches, jnp.asarray(pf.table), keys,
                    jnp.asarray(pf.sample_index), temps, top_ks, top_ps)
            else:
                first, self.caches = self._prefill_jit(pf.hot)(
                    self.params, jnp.asarray(pf.tokens),
                    jnp.asarray(pf.lens), jnp.asarray(pf.offsets),
                    jnp.asarray(pf.scale_base), self.caches,
                    jnp.asarray(pf.table), keys,
                    jnp.asarray(pf.sample_index), temps, top_ks, top_ps)
            if pf.emit:
                prefill_tok = fresh = first
        dc = plan.decode
        decode_tok = None
        if dc is not None and self.spec_k:
            fresh_mask = jnp.asarray(dc.fresh)
            decode_tok, self._tok_buf, self.caches, self.draft_caches = (
                self._spec_jit(dc.hot)(
                    self.params, self._tok_buf, fresh, fresh_mask,
                    jnp.asarray(dc.live), jnp.asarray(dc.pos),
                    jnp.asarray(dc.n_tok), self.caches, self.draft_caches,
                    jnp.asarray(dc.table), jnp.asarray(dc.base), keys,
                    jnp.asarray(dc.sample_index), temps, top_ks, top_ps))
            self.ticks += 1
        elif dc is not None:
            fresh_mask = jnp.asarray(dc.fresh)
            decode_tok, self.caches = self._decode_jit(dc.hot)(
                self.params, self._tok_buf, fresh, fresh_mask,
                jnp.asarray(dc.live), jnp.asarray(dc.pos),
                jnp.asarray(dc.kv_len), self.caches, jnp.asarray(dc.table),
                jnp.asarray(dc.base), keys, jnp.asarray(dc.sample_index),
                temps, top_ks, top_ps)
            self._tok_buf = decode_tok
            self.ticks += 1
        elif pf is not None and pf.emit:
            # prefill completed with no decode tick in the same plan (the
            # prefill()-only driver, or all completions at max_new == 1):
            # fold the first-token samples into the on-device buffer so
            # the NEXT tick's decode still never needs a host round-trip
            mask = np.zeros(self.max_batch, bool)
            mask[[e.slot for e in pf.emit]] = True
            self._tok_buf = jnp.where(jnp.asarray(mask), fresh,
                                      self._tok_buf)
        return _InFlight(prefill_tok, pf.emit if pf else (),
                         decode_tok, dc.emit if dc else (),
                         bool(self.spec_k and dc is not None))

    def _read(self, arr: jax.Array) -> np.ndarray:
        """THE host<->device readback (token ids only); instrumented so
        benchmarks report the host-idle fraction and tests can assert the
        one-readback-per-tick invariant."""
        t0 = time.perf_counter()
        out = np.asarray(arr)
        self.blocked_s += time.perf_counter() - t0
        self.readbacks += 1
        return out

    def _collect(self, inflight: _InFlight) -> List[RequestOutput]:
        """Read a dispatched tick's sampled ids and surface them (first
        prefill samples, then decode samples — the sync event order).

        Speculative ticks read ONE packed (B, m+1) array — per-slot
        target samples plus the accepted count — and settle each slot's
        emit run through ``Scheduler.resolve_spec`` (accepted prefix
        ingested, rejected suffix dropped + rolled back).

        ``self._settling`` mirrors the not-yet-settled emits (in settle
        order) so crash containment can balance the in-flight accounting
        when a readback raises partway through."""
        events: List[RequestOutput] = []
        self._settling = list(inflight.prefill_emit + inflight.decode_emit)
        if inflight.prefill_emit:
            vals = self._read(inflight.prefill_tok)
            for e in inflight.prefill_emit:
                out = self.sched.ingest(e, int(vals[e.slot]))
                self._settling.pop(0)
                if out is not None:
                    events.append(out)
        if inflight.decode_emit:
            vals = self._read(inflight.decode_tok)
            if inflight.spec:
                groups: "dict[int, List[Emit]]" = {}
                for e in inflight.decode_emit:  # slot-major consecutive
                    groups.setdefault(e.slot, []).append(e)
                for slot, ems in groups.items():  # insertion == settle order
                    events.extend(self.sched.resolve_spec(
                        slot, tuple(ems), vals[slot],
                        int(vals[slot, -1])))
                    del self._settling[:len(ems)]
            else:
                for e in inflight.decode_emit:
                    out = self.sched.ingest(e, int(vals[e.slot]))
                    self._settling.pop(0)
                    if out is not None:
                        events.append(out)
        return events

    # ------------------------------------------------------------------
    # crash containment (the engine loops below route through this)
    # ------------------------------------------------------------------
    def _fail_tick(self, exc: BaseException,
                   unsettled: List[Emit]) -> List[RequestOutput]:
        """Contain one failed device tick: settle the in-flight
        accounting for every sample that will never be read (`unsettled`
        plus anything still dispatched-ahead), reset the on-device token
        buffer, and fail the ACTIVE/RETIRING requests with
        ``finish_reason="error"`` (pages invalidated + freed; see
        ``Scheduler.fail_active``).  QUEUED requests are untouched — a
        preempted request's lost sample regenerates bit-identically on
        resume (keyed sampling) — so the engine keeps serving."""
        for e in unsettled:
            self.sched.drop(e)
        if self._pending is not None:  # the dispatched-ahead tick is lost
            for e in self._pending.prefill_emit + self._pending.decode_emit:
                self.sched.drop(e)
            self._pending = None
        self._settling = []
        self._tok_buf = self._zero_tok  # device token state is suspect
        self.tick_errors += 1
        self.last_error = f"{type(exc).__name__}: {exc}"
        log.warning("device tick failed (%s); failing in-flight requests "
                    "and continuing", self.last_error, exc_info=exc)
        return self.sched.fail_active(self.last_error)

    @staticmethod
    def _plan_emits(plan: TickPlan) -> List[Emit]:
        ems: List[Emit] = []
        if plan.prefill is not None:
            ems.extend(plan.prefill.emit)
        if plan.decode is not None:
            ems.extend(plan.decode.emit)
        return ems

    def _run_plan(self, plan: TickPlan) -> List[RequestOutput]:
        """Dispatch + read one plan, containing device failures (the
        sync-path tick body).  Planning itself stays OUTSIDE containment:
        it is host-pure, so an exception there is a scheduler bug, not a
        device fault to absorb."""
        try:
            inflight = self._dispatch(plan)
        except Exception as e:
            return self._fail_tick(e, self._plan_emits(plan))
        try:
            return self._collect(inflight)
        except Exception as e:
            return self._fail_tick(e, list(self._settling))

    # ------------------------------------------------------------------
    # the engine loops
    # ------------------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """One SYNCHRONOUS engine tick: plan, dispatch, read.  Returns
        this tick's streamed outputs (empty when the engine is idle)."""
        self.faults.advance()
        self._fault_delay()
        plan = self.sched.plan_tick()
        events = self.sched.take_events()  # timeouts expired at plan time
        events.extend(self._run_plan(plan))
        return events

    def prefill(self, admitted: Optional[List[Admission]] = None
                ) -> List[RequestOutput]:
        """Drive the PREFILLING slots to completion (no admissions, no
        decode ticks) and return their first-token outputs.  ``admitted``
        is accepted for API compatibility with ``prefill(schedule())``;
        the scheduler already tracks the slots."""
        del admitted
        events: List[RequestOutput] = []
        while self.sched.has_prefilling:
            self.faults.advance()
            plan = self.sched.plan_tick(admit=False, decode=False)
            events.extend(self.sched.take_events())
            events.extend(self._run_plan(plan))
        return events

    def _fault_delay(self) -> None:
        d = self.faults.delay("tick.delay")
        if d > 0:
            time.sleep(d)  # chaos: a straggling device / slow shard

    def poll(self) -> List[RequestOutput]:
        """ONE engine iteration honoring ``mode``; the unit external
        drivers (``stream()``, the network gateway's pump thread, the
        traffic-SLO load benchmark) build their loops from.  Safe to call
        when idle (returns ``[]``); new submissions between polls join
        the next tick — continuous-batching admission under live traffic.

        ``mode="sync"``: plan + dispatch + read one tick.
        ``mode="overlap"``: dispatch tick ``t+1`` BEFORE reading tick
        ``t`` — the device starts on the next forward while the host
        ingests tokens, detects finishes, and plans (the overlap the
        paper's pipelined search/contextualization story calls for).
        The returned outputs are therefore those of the PREVIOUS poll's
        tick; keep polling until ``has_pending`` clears to drain.

        A device failure in either mode is CONTAINED: the tick's
        in-flight requests finish with ``finish_reason="error"``, their
        pages free, and the engine keeps serving (``tick_errors``
        counts; see ``_fail_tick``)."""
        if self.mode == "sync":
            return self.step() if self.has_work else []
        self.faults.advance()
        self._fault_delay()
        inflight = None
        events: List[RequestOutput] = []
        if self.has_work:
            plan = self.sched.plan_tick()
            events.extend(self.sched.take_events())
            try:
                inflight = self._dispatch(plan)
            except Exception as e:
                events.extend(self._fail_tick(e, self._plan_emits(plan)))
                return events
        pending, self._pending = self._pending, None
        if pending is not None:
            try:
                events.extend(self._collect(pending))
            except Exception as e:
                unsettled = list(self._settling)
                if inflight is not None:  # the new tick dies with the device
                    unsettled.extend(inflight.prefill_emit
                                     + inflight.decode_emit)
                events.extend(self._fail_tick(e, unsettled))
                return events
        self._pending = (None if inflight is None or inflight.empty
                         else inflight)
        return events

    def stream(self, *requests: Request) -> Iterator[RequestOutput]:
        """Submit `requests` (if given) and drive the engine, yielding
        each generated token as a RequestOutput until the pool drains.
        Token-for-token identical between ``mode="sync"`` and
        ``mode="overlap"`` (and to ``run()``): same per-request rng, same
        per-request tick schedule."""
        for r in requests:
            self.submit(r)
        while self.has_work or self.has_pending:
            yield from self.poll()

    def run(self) -> List[Request]:
        """Drain the engine; returns completed requests in finish order."""
        for _ in self.stream():
            pass
        return self.sched.done
