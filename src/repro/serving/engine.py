"""Batched serving engine with continuous batching.

Slot-based scheduling over a fixed decode batch: finished sequences free
their slot, queued prompts are prefilled (batch-of-one) and spliced into
the shared KV cache at the free slot, and every engine step decodes all
active slots at their own positions (ragged positions / kv lengths are
native to the attention masking).  With `attn_mode="camformer"` the cache
stores bit-packed keys and each step performs the paper's CAM search +
two-stage top-k against the growing cache.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import cast_params
from repro.models.transformer import dtype_of
from repro.serving import sampler as S

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    rid: int = 0
    tokens: Optional[List[int]] = None  # generated


class ServeEngine:
    def __init__(self, md, cfg, params, *, max_batch: int = 8,
                 max_len: int = 512, seed: int = 0):
        self.md, self.cfg = md, cfg
        self.params = cast_params(params, dtype_of(cfg))
        self.max_batch, self.max_len = max_batch, max_len
        self.rng = jax.random.PRNGKey(seed)

        caches = md.cache_specs(cfg, max_batch, max_len)
        is_leaf = lambda x: (isinstance(x, tuple) and len(x) == 2
                             and isinstance(x[0], jax.ShapeDtypeStruct))
        self.caches = jax.tree.map(
            lambda t: jnp.zeros(t[0].shape, t[0].dtype), caches, is_leaf=is_leaf)

        self.pos = np.zeros(max_batch, np.int32)  # next position per slot
        self.active: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, pos, kvl, c: md.decode(p, t, pos, kvl, c, cfg))
        self._prefill = jax.jit(
            lambda p, b, c: md.prefill(p, b, c, cfg))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.tokens = []
        self.queue.append(req)

    def _splice_cache(self, slot: int, one_cache):
        """Insert a batch-of-one prefill cache into the shared cache."""
        def ins(big, small):
            if big.ndim < 2:
                return big
            # batch axis: layer-stacked leaves -> axis 1; flat leaves -> 0
            ax = 1 if big.shape[0] == small.shape[0] and big.ndim == small.ndim and big.shape[1] == self.max_batch else 0
            idx = [slice(None)] * big.ndim
            idx[ax] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small)
        self.caches = jax.tree.map(ins, self.caches, one_cache)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            one_caches = jax.tree.map(
                lambda t: jnp.zeros(
                    (t.shape[0], 1) + t.shape[2:], t.dtype)
                if t.ndim >= 2 and t.shape[1] == self.max_batch
                else jnp.zeros((1,) + t.shape[1:], t.dtype),
                self.caches)
            batch = {"tokens": prompt}
            logits, one_caches = self._prefill(self.params, batch, one_caches)
            self._splice_cache(slot, one_caches)
            first = int(S.greedy(logits)[0]) if req.temperature == 0.0 else int(
                S.sample(logits, self._next_rng(), temperature=req.temperature)[0])
            req.tokens.append(first)
            self.active[slot] = req
            self.pos[slot] = len(req.prompt)

    def _next_rng(self):
        self.rng, sub = jax.random.split(self.rng)
        return sub

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit new requests, decode all active slots."""
        self._admit()
        if not any(r is not None for r in self.active):
            return False
        tokens = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(self.active):
            if r is not None:
                tokens[i] = r.tokens[-1]
        pos = jnp.asarray(self.pos)
        kv_len = jnp.asarray(self.pos + 1)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens), pos, kv_len, self.caches)
        nxt = S.greedy(logits)
        nxt_host = np.asarray(nxt)
        for i, r in enumerate(self.active):
            if r is None:
                continue
            r.tokens.append(int(nxt_host[i]))
            self.pos[i] += 1
            if (len(r.tokens) >= r.max_new_tokens
                    or self.pos[i] >= self.max_len - 1):
                self.done.append(r)
                self.active[i] = None
        return True

    def run(self):
        while self.queue or any(r is not None for r in self.active):
            self.step()
        return self.done
