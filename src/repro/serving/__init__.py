"""Serving: paged KV cache + continuous-batching engine + samplers.

Public surface:

    from repro.serving import (ServeEngine, Request, SamplingParams,
                               RequestState, RequestOutput)

    eng = ServeEngine(md, cfg, params, max_batch=8, max_len=512)
    for out in eng.stream(Request(prompt=ids,
                                  sampling=SamplingParams(max_new=64))):
        print(out.rid, out.token, out.finished)

Robustness surface (serving/faults.py, ISSUE 10): bounded admission
(``max_queue`` + ``RejectionError``/``QueueFullError`` at submit),
per-request deadlines in ``SamplingParams``, crash containment
(``finish_reason="error"``), and the deterministic chaos harness:

    eng = ServeEngine(..., max_queue=64,
                      faults=parse_faults("step.error@3"))
"""

from repro.serving.engine import Admission, ServeEngine
from repro.serving.faults import (NO_FAULTS, FaultPlan, FaultSpec,
                                  InjectedFault, parse_faults)
from repro.serving.kv_cache import (PagedKVCache, PrefixMatch, TRASH_PAGE,
                                    pages_for)
from repro.serving.request import (Request, RequestOutput, RequestState,
                                   SamplingParams)
from repro.serving.scheduler import (QueueFullError, RejectionError,
                                     Scheduler, TickPlan)

__all__ = [
    "Admission", "ServeEngine", "Scheduler", "TickPlan", "PagedKVCache",
    "PrefixMatch", "TRASH_PAGE", "pages_for", "Request", "RequestOutput",
    "RequestState", "SamplingParams", "FaultPlan", "FaultSpec",
    "InjectedFault", "NO_FAULTS", "parse_faults", "RejectionError",
    "QueueFullError",
]
