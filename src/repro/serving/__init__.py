"""Serving: paged KV cache + continuous-batching engine + samplers.

Public surface:

    from repro.serving import (ServeEngine, Request, SamplingParams,
                               RequestState, RequestOutput)

    eng = ServeEngine(md, cfg, params, max_batch=8, max_len=512)
    for out in eng.stream(Request(prompt=ids,
                                  sampling=SamplingParams(max_new=64))):
        print(out.rid, out.token, out.finished)
"""

from repro.serving.engine import Admission, ServeEngine
from repro.serving.kv_cache import (PagedKVCache, PrefixMatch, TRASH_PAGE,
                                    pages_for)
from repro.serving.request import (Request, RequestOutput, RequestState,
                                   SamplingParams)
from repro.serving.scheduler import Scheduler, TickPlan

__all__ = [
    "Admission", "ServeEngine", "Scheduler", "TickPlan", "PagedKVCache",
    "PrefixMatch", "TRASH_PAGE", "pages_for", "Request", "RequestOutput",
    "RequestState", "SamplingParams",
]
