"""Host-side serving scheduler: admission, preemption, finish detection,
and page planning — NO device work.

The serving core is split into three parts (ISSUE 4 / ROADMAP "async /
overlapped engine loop"):

  * ``Scheduler`` (this module) — pure host state machine.  It owns the
    request queue, the slot table, the ``PagedKVCache`` allocator, and
    every per-slot numpy array the device step consumes.  One call to
    ``plan_tick()`` produces a ``TickPlan``: which requests admit (and
    which COW pages fork), the chunk of prompt each PREFILLING slot
    advances by this tick, and the decode batch (positions, page-table
    snapshot, sampling-parameter rows, per-request rng keys).  Planning
    NEVER reads a device value — everything it needs (positions, page
    counts, token budgets) is host-derivable, which is exactly what lets
    the engine dispatch tick ``t+1`` before reading tick ``t``.

  * the fused device step (``engine.py``) — consumes a ``TickPlan``,
    runs per-layer ``backend.paged_decode`` + cache write + vectorized
    keyed sampling inside ONE jit, and returns sampled token ids: the
    only per-tick readback.

  * the loop (``engine.py``) — sync (read every tick) or overlapped
    (dispatch-ahead: host visibility of token VALUES is deferred one
    tick; value-dependent events — stop tokens — are detected on
    ``ingest`` and at most one extra "zombie" tick runs for a stopped
    slot, writing only into pages that slot still owns).

Continuous chunked-prefill batching: a PREFILLING slot advances by
``prefill_slice`` tokens per tick (page-sized chunks) while DECODING
slots keep ticking — admission is no longer a stop-the-world batched
prefill.  ``prefill_slice=None`` prefills the whole suffix in the
admission tick (the classic regime).

Token attribution is positional, not slot-based: every dispatched sample
carries an ``Emit(slot, req, index)`` record, so tokens read back later
still reach the right request even if the slot was preempted, drained,
or reassigned in the meantime — and per-request ``(rid, index)`` rng
keys (``sampler.request_key``) make the sampled values independent of
tick scheduling entirely.
"""

from __future__ import annotations

import time
from typing import Callable, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.serving import sampler as S
from repro.serving.kv_cache import (NO_MATCH, TRASH_PAGE, PagedKVCache,
                                    pages_for)
from repro.serving.request import Request, RequestOutput, RequestState

__all__ = ["Admission", "Emit", "PrefillChunk", "DecodeTick", "TickPlan",
           "Scheduler", "RejectionError", "QueueFullError"]


class RejectionError(ValueError):
    """Admission control refused a request at submit: it can NEVER run
    (empty prompt, exceeds max_len, needs more pages than the pool has).
    A ValueError subclass so seed-era callers catching ValueError keep
    working."""


class QueueFullError(RejectionError):
    """Admission control refused a request because the bounded queue is
    at capacity — a RETRYABLE condition (the gateway maps it to HTTP 429
    + Retry-After, unlike never-fit rejections' 503)."""


class Admission(NamedTuple):
    """One scheduling decision: where a request lands and what it shares."""

    slot: int
    req: Request
    resume_from: int  # generated tokens carried across a preemption
    matched: int  # prefix tokens served from shared pages (0 = none)
    forks: Tuple[Tuple[int, int], ...]  # (src, dst) COW page copies


class Emit(NamedTuple):
    """Attribution of one dispatched sample: generated-token ``index`` of
    ``req``, computed in batch row ``slot`` at dispatch time."""

    slot: int
    req: Request
    index: int


class PrefillChunk(NamedTuple):
    """One tick's prefill work: each PREFILLING row advances by one chunk
    of its (suffix of) prompt; rows with ``lens == 0`` are inactive and
    write to the trash page."""

    tokens: np.ndarray  # (B, S) right-padded chunk batch
    lens: np.ndarray  # (B,) TOTAL valid tokens after this chunk (0 = idle)
    offsets: np.ndarray  # (B,) first position written this chunk
    scale_base: np.ndarray  # (B,) k_scale origin (prefix-sharing offset)
    table: np.ndarray  # (B, P) page-table snapshot, idle rows trashed
    sample_index: np.ndarray  # (B,) generated-token index sampled per row
    hot: bool  # any completing row samples with temperature > 0
    emit: Tuple[Emit, ...]  # completing rows: first-token attribution


class DecodeTick(NamedTuple):
    """One tick's decode work over every DECODING row.

    Multi-token ticks (speculative decoding, future Medusa-style heads):
    ``n_tok[i] > 1`` means row ``i`` dispatches ``n_tok[i]`` consecutive
    generated-token indices this tick — its ``emit`` records are
    slot-major consecutive — and ``pos[i]`` is the FIRST position
    written.  Plain decode is the ``n_tok == 1`` degenerate case.
    """

    pos: np.ndarray  # (B,) first position written this tick
    kv_len: np.ndarray  # (B,) pos+1 for live rows, 0 for inert rows
    base: np.ndarray  # (B,) prefix-sharing offset
    table: np.ndarray  # (B, P) page-table snapshot, inert rows trashed
    sample_index: np.ndarray  # (B,) FIRST generated-token index per row
    live: np.ndarray  # (B,) bool — rows decoding this tick
    fresh: np.ndarray  # (B,) bool — input token comes from THIS tick's
    #                     prefill sample (first decode after admission)
    hot: bool  # any live row samples with temperature > 0
    emit: Tuple[Emit, ...]
    n_tok: Optional[np.ndarray] = None  # (B,) tokens dispatched per row
    #                     (None <=> all-ones: the plain single-token tick)


class TickPlan(NamedTuple):
    """Everything the device step needs for one tick, host-computed."""

    forks: Tuple[Tuple[int, int], ...]  # COW copies, dispatched first
    prefill: Optional[PrefillChunk]
    decode: Optional[DecodeTick]
    keys: np.ndarray  # (B, 2) uint32 per-request raw rng key data
    temps: np.ndarray  # (B,) float32 per-slot sampling params
    top_ks: np.ndarray  # (B,) int32
    top_ps: np.ndarray  # (B,) float32


class Scheduler:
    """Admission policy + per-tick work planning, host-pure.

    Mutates allocator state (reservations, refcounts, fork page ids) and
    per-slot numpy arrays, but runs NO model computation and reads NO
    device values.  The engine feeds sampled tokens back through
    ``ingest`` (token values are the ONLY device-derived input), which
    appends them to their requests, detects stop/length finishes, and
    retires slots.
    """

    def __init__(self, kv: PagedKVCache, *, max_batch: int, max_len: int,
                 seed: int = 0, prefix_sharing: bool = True,
                 prefill_slice: Optional[int] = None,
                 prefill_bucket: int = 16, spec_k: int = 0,
                 max_queue: Optional[int] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.kv = kv
        self.max_batch, self.max_len = max_batch, max_len
        self.seed = seed
        self.prefix_sharing = prefix_sharing
        if prefill_slice is not None and prefill_slice < 1:
            raise ValueError(f"prefill_slice must be >= 1, got {prefill_slice}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if max_queue is not None and max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {max_queue}")
        self.prefill_slice = prefill_slice
        self.prefill_bucket = prefill_bucket
        self.spec_k = spec_k
        self.max_queue = max_queue  # bounded admission (None = unbounded)
        self._clock = clock  # injectable for deterministic deadline tests

        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * max_batch
        self.done: List[Request] = []
        self.peak_pages = 0  # high-water mark of actively-owned pages
        self.preemptions = 0  # page-pressure evictions (gateway /metrics
        #                       and the traffic-SLO benchmark report this)
        self.spec_proposed = 0  # draft tokens proposed (spec_k > 0)
        self.spec_accepted = 0  # draft tokens the target verified
        self.timeouts = 0  # deadline/queue-timeout expiries (host-side)
        self.rejections = 0  # admission-control refusals (submit + reject)
        self.prefill_tokens = 0  # prompt tokens materialized via chunks
        self.prefill_ticks = 0  # ticks that carried a prefill chunk
        #  (gateway /metrics + serve_slo: TTFT attribution — a TTFT
        #   regression with flat chunk counters is a decode/queue problem,
        #   not a prefill-path one)
        # slots whose multi-token tick is dispatched but not yet resolved
        # (rollback may rewind their pos/dispatched/pages): excluded from
        # planning and drain until resolve_spec runs.  Keyed by slot,
        # valued by the Request identity so a preempt-then-reassign of
        # the slot never blocks (or rolls back) the new occupant.
        self._spec_unread: dict = {}

        b = max_batch
        self.pos = np.zeros(b, np.int32)  # next decode position per slot
        self.base = np.zeros(b, np.int32)  # prefix-sharing offset per slot
        self.progress = np.zeros(b, np.int32)  # prompt tokens materialized
        self.dispatched = np.zeros(b, np.int32)  # generated tokens dispatched
        self.max_toks = np.zeros(b, np.int32)  # generation budget per slot
        self.temps = np.zeros(b, np.float32)
        self.top_ks = np.zeros(b, np.int32)
        self.top_ps = np.ones(b, np.float32)
        self.keys = np.zeros((b, 2), np.uint32)

        self._next_rid = 0
        self._arrival = 0  # FIFO tiebreak within a priority class
        self._admissions = 0  # preemption tiebreak (evict newest first)
        self._inflight_total = 0  # dispatched samples not yet ingested
        self._pending_forks: List[Tuple[int, int]] = []  # COW copies due
        # drain-released requests (slot freed at plan time) whose final
        # token is still in flight: not queued, not active, but LIVE —
        # cancel() must still reach them
        self._retiring: List[Request] = []
        # terminal outputs produced DURING planning (timeouts, containment
        # failures): the engine drains these into its poll() return so
        # stream()/run() callers see them without an on_token callback
        self._events: List[RequestOutput] = []

    # ------------------------------------------------------------------
    # submission / cancellation
    # ------------------------------------------------------------------
    def never_fit(self, req: Request) -> Optional[str]:
        """Admission-control policy: reason this request can NEVER be
        served (no amount of waiting helps), or None if it could fit.
        Public so the gateway can veto before the request ever crosses
        onto the engine thread (-> HTTP 503)."""
        if not req.prompt:
            return "empty prompt"
        need = len(req.prompt) + req.sampling.max_new
        if need > self.max_len:
            return f"prompt+max_new {need} > max_len {self.max_len}"
        pages = pages_for(need, self.kv.page_size)
        if pages > self.kv.max_pages_per_seq:
            return (f"needs {pages} pages > max_pages_per_seq "
                    f"{self.kv.max_pages_per_seq}")
        if pages > self.kv.n_pages - 1:
            return f"needs {pages} pages; pool has {self.kv.n_pages - 1}"
        return None

    def queue_full(self, extra: int = 0) -> bool:
        """Bounded-admission check: would `extra` more submissions (e.g.
        a gateway's not-yet-drained backlog) overflow ``max_queue``?"""
        return (self.max_queue is not None
                and len(self.queue) + extra >= self.max_queue)

    def submit(self, req: Request) -> int:
        """Queue a request; returns its rid (auto-assigned when None).

        Raises :class:`RejectionError` for never-fit requests and
        :class:`QueueFullError` when the bounded queue is at capacity
        (both ValueError subclasses); the request is left untouched.
        """
        if getattr(req, "_inflight", 0):
            raise ValueError(
                f"request {req.rid} still has in-flight dispatched work")
        if req.rid is None:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid + 1)
        reason = self.never_fit(req)
        if reason is not None:
            self.rejections += 1
            raise RejectionError(f"request {req.rid}: {reason}")
        if self.queue_full():
            self.rejections += 1
            raise QueueFullError(
                f"request {req.rid}: queue full "
                f"({len(self.queue)} >= max_queue {self.max_queue})")
        req.state = RequestState.QUEUED
        req.tokens = []
        req.finish_reason = None
        req.error = None
        req._seq = self._arrival  # FIFO order, kept across preemption
        req._inflight = 0
        req._t_submit = self._clock()  # deadline_ms / queue_timeout_ms base
        req._admitted_once = False
        self._arrival += 1
        self.queue.append(req)
        return req.rid

    def cancel(self, rid: int) -> Optional[RequestOutput]:
        """Terminate a queued or running request NOW; running requests
        free their pages immediately (in-flight dispatched samples for it
        are discarded at ingest).  Returns the final output record, or
        None if rid is not live."""
        for qi, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(qi)
                return self._finish_now(r, "cancelled")
        for slot, r in enumerate(self.active):
            if r is not None and r.rid == rid:
                self.kv.release(slot)
                self.active[slot] = None
                return self._finish_now(r, "cancelled")
        for r in self._retiring:  # slot drained, final token in flight
            if r.rid == rid:
                self._retiring.remove(r)
                return self._finish_now(r, "cancelled")
        return None

    def reject(self, rid: int, reason: str) -> Optional[RequestOutput]:
        """Admission-control eviction seam: terminally reject a QUEUED
        request with ``finish_reason="rejected"`` and the human-readable
        `reason` in ``error``.  The public replacement for reaching into
        the queue's private ordering: load-shedding policies (gateway
        overload, operator action) name the rid and the scheduler does
        the bookkeeping.  Returns None if rid is not queued (running
        requests are past admission — use ``cancel``)."""
        for qi, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(qi)
                self.rejections += 1
                return self._finish_now(r, "rejected", error=reason)
        return None

    def _finish_now(self, req: Request, reason: str,
                    error: Optional[str] = None) -> RequestOutput:
        req.state = (RequestState.CANCELLED if reason == "cancelled"
                     else RequestState.FINISHED)
        req.finish_reason = reason
        req.error = error
        self.done.append(req)
        out = RequestOutput(
            rid=req.rid, token=None, index=len(req.tokens), state=req.state,
            finished=True, finish_reason=reason, tokens=tuple(req.tokens),
            error=error)
        if req.on_token:
            req.on_token(out)
        return out

    def take_events(self) -> List[RequestOutput]:
        """Drain terminal outputs produced during planning (timeouts,
        crash containment) for the engine's poll() return."""
        events, self._events = self._events, []
        return events

    # ------------------------------------------------------------------
    # admission policy
    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    @property
    def has_prefilling(self) -> bool:
        return any(r is not None and r.state is RequestState.PREFILLING
                   for r in self.active)

    def _max_tokens_of(self, req: Request) -> int:
        return min(req.sampling.max_new, self.max_len - len(req.prompt))

    def _next_queued_index(self) -> int:
        return min(range(len(self.queue)),
                   key=lambda i: (-self.queue[i].priority,
                                  self.queue[i]._seq))

    def _pick_victim(self, priority: int) -> Optional[int]:
        """Lowest-priority DECODING slot strictly below `priority`; among
        equals, the most recently admitted (least prefill to redo)."""
        best = None
        for slot, r in enumerate(self.active):
            # only DECODING slots are evictable: preempting a PREFILLING
            # slot would discard partially-materialized chunks for no gain
            if (r is None or r.state is not RequestState.DECODING
                    or r.priority >= priority):
                continue
            key = (r.priority, -r._admit_seq)
            if best is None or key < best[0]:
                best = (key, slot)
        return None if best is None else best[1]

    def _preempt(self, slot: int) -> None:
        req = self.active[slot]
        self.kv.release(slot)  # sharers keep refcounted pages alive
        self.active[slot] = None
        req.state = RequestState.QUEUED  # tokens kept: resume re-prefills
        self.queue.append(req)  # _seq unchanged: keeps its FIFO standing
        self.preemptions += 1

    def admit(self) -> List[Admission]:
        """Fill free slots from the queue, matching shared prefixes and
        preempting lower-priority decoders under page pressure.  Mutates
        allocator state but runs NO model computation — the tick's
        prefill chunks consume the resulting PREFILLING slots."""
        admitted: List[Admission] = []
        while self.queue:
            qi = self._next_queued_index()
            req = self.queue[qi]
            if req._inflight:
                # a preempted request's last dispatched token has not been
                # ingested yet (overlapped loop): re-admitting now would
                # both replay it via re-prefill AND append it at ingest
                break
            effective = req.prompt + req.tokens  # resume covers generated
            need = len(req.prompt) + req.sampling.max_new
            match = (self.kv.match_prefix(effective)
                     if self.prefix_sharing else NO_MATCH)
            if match.defer:
                break  # prefix pages materialize soon; retry next tick
            slot = next(
                (i for i, r in enumerate(self.active) if r is None), None)
            if slot is None or not self.kv.can_reserve(
                    need, slot, n_shared=len(match.shared), match=match):
                victim = self._pick_victim(req.priority)
                if victim is None:
                    break  # page pressure: wait for retirements
                self._preempt(victim)
                continue  # re-match: the release may have dropped pages
            self.queue.pop(qi)
            forks = self.kv.reserve_shared(slot, match, need)
            if self.prefix_sharing:
                self.kv.register_prefix(slot, effective)
            req.state = RequestState.PREFILLING
            req.prefix_matched = match.matched
            req._admitted_once = True  # queue_timeout_ms no longer applies
            req._admit_seq = self._admissions
            self._admissions += 1
            self.active[slot] = req  # slot is taken from this point on
            self.base[slot] = match.matched
            self.progress[slot] = match.matched
            self.dispatched[slot] = len(req.tokens)
            self.max_toks[slot] = self._max_tokens_of(req)
            sp = req.sampling
            self.temps[slot] = sp.temperature
            self.top_ks[slot] = sp.top_k
            self.top_ps[slot] = sp.top_p
            self.keys[slot] = S.request_key(self.seed, req.rid)
            self._pending_forks.extend(forks)  # drained by plan_tick
            admitted.append(Admission(
                slot, req, len(req.tokens), match.matched, tuple(forks)))
        # never-fit requests are rejected at submit() now, so a queue that
        # cannot admit here is only ever WAITING (page pressure, deferred
        # prefix, in-flight preempted sample) — no MemoryError escape hatch
        self.peak_pages = max(self.peak_pages, self.kv.used_pages)
        return admitted

    # legacy spelling (the seed-era engine API)
    schedule = admit

    # ------------------------------------------------------------------
    # per-tick work planning
    # ------------------------------------------------------------------
    def _drain_dispatched(self) -> None:
        """Release slots whose requests have dispatched their full token
        budget (a length finish is host-plannable): the pages free for
        this tick's admissions even though the final token value has not
        been read yet.  Ingest finishes the request when it arrives."""
        for slot, r in enumerate(self.active):
            if (r is not None and r.state is RequestState.DECODING
                    and self.dispatched[slot] >= self.max_toks[slot]
                    and self._spec_unread.get(slot) is not r):
                # a spec-unread slot may roll dispatched back below the
                # budget at resolve time — never drain it early
                self.kv.release(slot)
                self.active[slot] = None
                self._retiring.append(r)

    def _plan_prefill(self) -> Optional[PrefillChunk]:
        slots = [i for i, r in enumerate(self.active)
                 if r is not None and r.state is RequestState.PREFILLING]
        if not slots:
            return None
        b = self.max_batch
        chunks = {}
        for i in slots:
            r = self.active[i]
            eff = r.prompt + r.tokens
            remaining = len(eff) - int(self.progress[i])
            take = (remaining if self.prefill_slice is None
                    else min(remaining, self.prefill_slice))
            chunks[i] = eff[self.progress[i]:self.progress[i] + take]
        if self.prefill_slice is None:
            maxs = max(len(c) for c in chunks.values())
            s = min(-(-maxs // self.prefill_bucket) * self.prefill_bucket,
                    self.max_len)
        else:
            s = min(self.prefill_slice, self.max_len)
        tokens = np.zeros((b, s), np.int32)
        lens = np.zeros(b, np.int32)
        offsets = np.zeros(b, np.int32)
        scale_base = np.zeros(b, np.int32)
        sample_index = np.zeros(b, np.int32)
        emit: List[Emit] = []
        hot = False
        for i in slots:
            r = self.active[i]
            chunk = chunks[i]
            tokens[i, :len(chunk)] = chunk
            offsets[i] = self.progress[i]
            scale_base[i] = self.base[i]
            lens[i] = self.progress[i] + len(chunk)
            self.progress[i] += len(chunk)
            if self.progress[i] == len(r.prompt) + len(r.tokens):
                # last chunk: this row samples its first generated token
                r.state = RequestState.DECODING
                self.pos[i] = self.progress[i]
                sample_index[i] = self.dispatched[i]
                emit.append(Emit(i, r, int(self.dispatched[i])))
                r._inflight += 1
                self._inflight_total += 1
                self.dispatched[i] += 1
                hot = hot or self.temps[i] > 0
                self.kv.commit_pages(self.kv.owned(i))
        self.prefill_tokens += sum(len(c) for c in chunks.values())
        self.prefill_ticks += 1
        table = np.where(lens[:, None] > 0, self.kv.table, TRASH_PAGE)
        return PrefillChunk(tokens, lens, offsets, scale_base, table,
                            sample_index, bool(hot), tuple(emit))

    def _plan_decode(self, fresh_slots: Tuple[int, ...]) -> Optional[DecodeTick]:
        live = [i for i, r in enumerate(self.active)
                if (r is not None and r.state is RequestState.DECODING
                    and self.dispatched[i] < self.max_toks[i]
                    and self._spec_unread.get(i) is not r)]
        if not live:
            return None
        b = self.max_batch
        live_mask = np.zeros(b, bool)
        live_mask[live] = True
        fresh = np.zeros(b, bool)
        fresh[[i for i in fresh_slots if live_mask[i]]] = True
        pos = self.pos.copy()
        kv_len = np.where(live_mask, self.pos + 1, 0).astype(np.int32)
        table = np.where(live_mask[:, None], self.kv.table, TRASH_PAGE)
        sample_index = self.dispatched.copy()
        n_tok = np.where(live_mask, 1, 0).astype(np.int32)
        emit = []
        hot = False
        for i in live:
            r = self.active[i]
            # multi-token tick: dispatch up to spec_k drafts + 1 sample,
            # capped at the slot's remaining generation budget; the
            # resolve step rolls back whatever the target rejects
            m = (1 if self.spec_k == 0 else
                 min(self.spec_k + 1,
                     int(self.max_toks[i] - self.dispatched[i])))
            n_tok[i] = m
            for j in range(m):
                emit.append(Emit(i, r, int(self.dispatched[i]) + j))
            r._inflight += m
            self._inflight_total += m
            self.dispatched[i] += m
            self.pos[i] += m
            if self.spec_k > 0:
                self._spec_unread[i] = r
            hot = hot or self.temps[i] > 0
        return DecodeTick(pos, kv_len, self.base.copy(), table, sample_index,
                          live_mask, fresh, bool(hot), tuple(emit),
                          n_tok if self.spec_k else None)

    def _expire(self) -> None:
        """Enforce per-request deadlines host-side (start of every
        plan_tick): queued requests past ``queue_timeout_ms`` (first
        admission only) or ``deadline_ms``, and running requests past
        ``deadline_ms``, finish NOW with ``finish_reason="timeout"``.
        No device work is interrupted — an expired running slot releases
        its pages and any still-in-flight sample for it is discarded at
        ingest, exactly like cancellation."""
        now = self._clock()
        for r in list(self.queue):
            sp = r.sampling
            if sp.deadline_ms is None and sp.queue_timeout_ms is None:
                continue
            waited_ms = (now - r._t_submit) * 1e3
            qto = None if r._admitted_once else sp.queue_timeout_ms
            bounds = [b for b in (qto, sp.deadline_ms) if b is not None]
            bound = min(bounds) if bounds else None
            if bound is not None and waited_ms > bound:
                self.queue.remove(r)
                self.timeouts += 1
                self._events.append(self._finish_now(
                    r, "timeout",
                    error=f"expired after {waited_ms:.0f}ms in queue "
                          f"(bound {bound:g}ms)"))
        for slot, r in enumerate(self.active):
            if r is None or r.sampling.deadline_ms is None:
                continue
            age_ms = (now - r._t_submit) * 1e3
            if age_ms > r.sampling.deadline_ms:
                self.kv.release(slot)
                self.active[slot] = None
                self.timeouts += 1
                self._events.append(self._finish_now(
                    r, "timeout",
                    error=f"deadline_ms {r.sampling.deadline_ms:g} "
                          f"exceeded ({age_ms:.0f}ms)"))

    def fail_active(self, error: str) -> List[RequestOutput]:
        """Crash containment: a device tick died before its samples could
        be read, so every ACTIVE and RETIRING request — whose in-flight
        work and (for actives) cache writes are lost — finishes with
        ``finish_reason="error"``.  Suspect exclusively-owned pages are
        invalidated (registry claims dropped; they free rather than
        retain) before release.  QUEUED requests survive untouched: a
        preempted request's lost sample regenerates bit-identically on
        resume (keyed sampling).  The ENGINE settles the in-flight
        accounting by ``drop``-ing the failed tick's emits; this method
        only retires state.  Pending COW forks and unread speculative
        ticks die with the tick that would have consumed them."""
        events: List[RequestOutput] = []
        for slot, r in enumerate(self.active):
            if r is None:
                continue
            self.kv.invalidate(slot)
            self.kv.release(slot)
            self.active[slot] = None
            events.append(self._finish_now(r, "error", error=error))
        for r in list(self._retiring):
            self._retiring.remove(r)
            events.append(self._finish_now(r, "error", error=error))
        self._spec_unread.clear()
        self._pending_forks = []
        return events

    def plan_tick(self, *, admit: bool = True,
                  decode: bool = True) -> TickPlan:
        """Plan one engine tick: admissions + one prefill chunk per
        PREFILLING slot + one decode step per DECODING slot.  Host-pure;
        the engine dispatches the plan and (eventually) feeds the sampled
        tokens back through ``ingest``."""
        self._expire()
        self._drain_dispatched()
        if admit:
            self.admit()
        # forks accumulate on admission (whether via plan_tick or a
        # direct schedule() call) and dispatch ONCE, before any write
        forks, self._pending_forks = self._pending_forks, []
        prefill = self._plan_prefill()
        dec = (self._plan_decode(tuple(e.slot for e in prefill.emit)
                                 if prefill else ())
               if decode else None)
        return TickPlan(tuple(forks), prefill, dec, self.keys.copy(),
                        self.temps.copy(), self.top_ks.copy(),
                        self.top_ps.copy())

    # ------------------------------------------------------------------
    # host visibility (the only device-derived input)
    # ------------------------------------------------------------------
    def ingest(self, emit: Emit, token: int) -> Optional[RequestOutput]:
        """Record one sampled token read back from the device.  Appends
        it to its request, detects stop/length finishes, retires the slot
        (unless it was already drain-released or preempted), and emits
        the streamed output.  Returns None for discarded samples: the
        request was cancelled, or already finished on an earlier stop
        token (the overlapped loop's zombie tick)."""
        slot, req, idx = emit
        req._inflight -= 1
        self._inflight_total -= 1
        if req.state.is_terminal or idx != len(req.tokens):
            return None  # cancelled / stopped earlier: drop the sample
        req.tokens.append(token)
        reason = None
        if token in req.sampling.stop:
            reason = "stop"
        elif len(req.tokens) >= self._max_tokens_of(req):
            reason = "length"
        if reason is not None:
            req.state = RequestState.FINISHED
            req.finish_reason = reason
            if self.active[slot] is req:  # not drained / reassigned
                self.kv.release(slot)
                self.active[slot] = None
            elif req in self.queue:  # preempted, finished by its last token
                self.queue.remove(req)
            elif req in self._retiring:  # drain-released at plan time
                self._retiring.remove(req)
            self.done.append(req)
        out = RequestOutput(
            rid=req.rid, token=token, index=len(req.tokens),
            state=req.state, finished=reason is not None,
            finish_reason=reason, tokens=tuple(req.tokens))
        if req.on_token:
            req.on_token(out)
        return out

    def drop(self, emit: Emit) -> None:
        """Discard a dispatched sample without surfacing it (a rejected
        speculative suffix position): balances the in-flight accounting
        that ``ingest`` would otherwise settle."""
        emit.req._inflight -= 1
        self._inflight_total -= 1

    @property
    def spec_acceptance(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.spec_accepted / max(self.spec_proposed, 1)

    def resolve_spec(self, slot: int, emits: Tuple[Emit, ...],
                     tokens, n_valid: int) -> List[RequestOutput]:
        """Settle one slot's multi-token tick: ingest the accepted prefix
        (``tokens[:n_valid]`` at the first ``n_valid`` emits), drop the
        rejected suffix, and roll the slot's host state AND paged cache
        back to the last valid position (``truncate_to`` + re-grow to the
        admission reservation; the boundary-fork copies it may produce
        join the next tick's COW dispatch).  A slot that was preempted,
        cancelled, or finished (stop token inside the valid run) in the
        meantime only settles attribution — its pages are no longer ours
        to rewind.  Rollback re-growth that loses the page-pressure race
        preempts the request (it resumes via re-prefill, token-exact)."""
        req = emits[0].req
        n_tok = len(emits)
        if self._spec_unread.get(slot) is req:
            del self._spec_unread[slot]
        if n_tok > 1:
            self.spec_proposed += n_tok - 1
            self.spec_accepted += n_valid - 1
        events: List[RequestOutput] = []
        for j, e in enumerate(emits):
            if j < n_valid:
                out = self.ingest(e, int(tokens[j]))
                if out is not None:
                    events.append(out)
            else:
                self.drop(e)
        excess = n_tok - n_valid
        if excess > 0 and self.active[slot] is req:
            self.dispatched[slot] -= excess
            self.pos[slot] -= excess
            try:
                forks = self.kv.truncate_to(slot, int(self.pos[slot]))
                self.kv.reserve(
                    slot, len(req.prompt) + req.sampling.max_new)
                self._pending_forks.extend(forks)
            except MemoryError:
                self._preempt(slot)
        return events
