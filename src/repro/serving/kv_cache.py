"""Paged, bit-packed KV cache for the serving engine.

Memory layout (per transformer layer, stacked along a leading `layers` axis
by the model's ``page_specs``):

  * ``kp_pages``: (n_pages, H_kv, page_size, d/32) uint32 — keys bit-packed
    exactly as ``core/binarize`` + ``core/bacam.pack_bits`` produce them
    (the paper's Key SRAM holds binarized keys; 6.25% of the bf16 footprint).
  * ``v_pages``:  (n_pages, H_kv, page_size, d) model dtype — fp16/bf16
    values, gathered sparsely (only the top-k selected rows) at attend time.
  * ``k_scale``:  (max_batch, H_kv) float32 — running per-slot/head key
    scale (softmax temperature bookkeeping; per sequence, not per page).

Sequences own *pages*, not contiguous ``max_len`` spans: a slot's logical
token position ``p`` lives at row ``p % page_size`` of physical page
``page_table[slot, p // page_size]``.  The page table is host-managed by a
free-list allocator and shared by every layer (all layers append in
lockstep, vLLM-style), so continuous batching admits requests whenever
pages — not a whole ``max_len`` slot reservation — are available.

Physical page 0 is reserved as the TRASH page: page-table rows of inactive
or padded slots point at it, so their (masked, never-read) cache writes land
somewhere harmless instead of clobbering live sequences.
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

__all__ = ["PagedKVCache", "TRASH_PAGE", "pages_for"]

TRASH_PAGE = 0  # physical page 0 is never allocated


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold n_tokens."""
    return -(-max(n_tokens, 0) // page_size)


@dataclasses.dataclass
class PagedKVCache:
    """Host-side page-table + free-list allocator over the device pools.

    The device-side pools themselves live with the engine (they are jitted
    function state); this object owns which physical page belongs to which
    slot and hands out / reclaims pages.
    """

    n_pages: int
    page_size: int
    max_batch: int
    max_pages_per_seq: int

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        # LIFO free list; page 0 reserved as the trash page.
        self._free: List[int] = list(range(self.n_pages - 1, TRASH_PAGE, -1))
        self._owned: List[List[int]] = [[] for _ in range(self.max_batch)]
        self.table = np.full((self.max_batch, self.max_pages_per_seq),
                             TRASH_PAGE, np.int32)

    # -- capacity ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def can_reserve(self, n_tokens: int, slot: int | None = None) -> bool:
        """Can a (possibly partially-grown) slot cover n_tokens total?"""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:  # reserve() would refuse
            return False
        have = len(self._owned[slot]) if slot is not None else 0
        return need - have <= len(self._free)

    # -- alloc / free --------------------------------------------------
    def reserve(self, slot: int, n_tokens: int) -> None:
        """Grow `slot` to cover n_tokens logical tokens (idempotent)."""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        owned = self._owned[slot]
        while len(owned) < need:
            if not self._free:
                raise MemoryError(
                    f"page pool exhausted growing slot {slot} to "
                    f"{n_tokens} tokens")
            page = self._free.pop()
            self.table[slot, len(owned)] = page
            owned.append(page)

    def release(self, slot: int) -> None:
        """Return all of `slot`'s pages to the free list."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self.table[slot, :] = TRASH_PAGE

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])
