"""Paged, bit-packed KV cache for the serving engine.

Memory layout (per transformer layer, stacked along a leading `layers` axis
by the model's ``page_specs``):

  * ``kp_pages``: (n_pages, H_kv, page_size, d/32) uint32 — keys bit-packed
    exactly as ``core/binarize`` + ``core/bacam.pack_bits`` produce them
    (the paper's Key SRAM holds binarized keys; 6.25% of the bf16 footprint).
  * ``v_pages``:  (n_pages, H_kv, page_size, d) model dtype — fp16/bf16
    values, gathered sparsely (only the top-k selected rows) at attend time.
  * ``k_scale``:  (max_batch, H_kv) float32 — running per-slot/head key
    scale (softmax temperature bookkeeping; per sequence, not per page).

Sequences own *pages*, not contiguous ``max_len`` spans: a slot's logical
token position ``p`` lives at row ``p % page_size`` of physical page
``page_table[slot, p // page_size]``.  The page table is host-managed by a
free-list allocator and shared by every layer (all layers append in
lockstep, vLLM-style), so continuous batching admits requests whenever
pages — not a whole ``max_len`` slot reservation — are available.

Physical page 0 is reserved as the TRASH page: page-table rows of inactive
or padded slots point at it, so their (masked, never-read) cache writes land
somewhere harmless instead of clobbering live sequences.

Copy-on-write prefix sharing
----------------------------

Pages are REFCOUNTED: several slots may alias one physical page (a shared
system-prompt prefix is prefilled once), and a page returns to the free
list only when its last owner releases it.  Sharing is discovered by
hash-based prefix matching at admission:

  * every admitted prompt registers its full pages under a cumulative
    chain key ``(parent_key, page_tokens)`` and its partial last page (if
    any) under ``(chain_key, tail_tokens)``;
  * ``match_prefix`` walks a new prompt down the chain, collecting the
    longest registered prefix.  Fully-covered pages are attached
    read-only (refcount++).  If the match ends mid-page — the page that
    would receive this request's first KV write — that page is FORKED:
    a fresh physical page is allocated and the engine copies the page's
    contents device-side before prefill (copy-on-write, performed eagerly
    at admission because the write is guaranteed).

Shared pages are never written: a sharer's first computed position is
``matched`` and full shared pages only cover positions below it, while
the mid-page boundary case gets a private fork.  The match is always
capped at ``len(prompt) - 1`` so at least one prompt token is computed
(prefill needs a final hidden state to sample from).

Pages registered in the CURRENT admission round are "pending" — their
contents materialize only when the batched prefill runs — so a prompt
matching a pending page reports ``defer=True`` and the engine retries
next tick (one tick of latency buys chunked-prefill-safe sharing).
There is no retention: a prefix is shareable only while some live slot
still holds its pages.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Set, Tuple

import numpy as np

__all__ = ["PagedKVCache", "PrefixMatch", "NO_MATCH", "TRASH_PAGE",
           "pages_for"]

TRASH_PAGE = 0  # physical page 0 is never allocated


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold n_tokens."""
    return -(-max(n_tokens, 0) // page_size)


class PrefixMatch(NamedTuple):
    """Result of hash-matching a prompt against the registered prefixes."""

    matched: int  # tokens covered by shared pages (+ fork), < len(prompt)
    shared: Tuple[int, ...]  # full pages attached read-only (refcount++)
    fork_src: Optional[int]  # page to copy-on-write fork, or None
    defer: bool  # prefix registered this tick but not yet prefilled


NO_MATCH = PrefixMatch(0, (), None, False)


@dataclasses.dataclass
class PagedKVCache:
    """Host-side page-table + free-list allocator over the device pools.

    The device-side pools themselves live with the engine (they are jitted
    function state); this object owns which physical page belongs to which
    slot, hands out / reclaims pages, and tracks refcounts + the prefix
    registry for copy-on-write page sharing.
    """

    n_pages: int
    page_size: int
    max_batch: int
    max_pages_per_seq: int

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        # LIFO free list; page 0 reserved as the trash page.
        self._free: List[int] = list(range(self.n_pages - 1, TRASH_PAGE, -1))
        self._owned: List[List[int]] = [[] for _ in range(self.max_batch)]
        self.table = np.full((self.max_batch, self.max_pages_per_seq),
                             TRASH_PAGE, np.int32)
        # page_refs[p] == number of slots whose page table references p;
        # 0 <=> p is free (or the trash page).
        self.page_refs = np.zeros(self.n_pages, np.int32)
        # prefix registry: chain key -> page (full pages), and
        # (chain key, tail tokens) -> (page, rows) for a partial last page.
        self._prefix: Dict[tuple, int] = {}
        self._tail: Dict[tuple, Tuple[int, int]] = {}
        self._page_keys: Dict[int, List[tuple]] = {}  # page -> registry keys
        self._pending: Set[int] = set()  # registered, not yet prefilled

    # -- capacity ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        """UNIQUE physical pages in use (shared pages count once)."""
        return (self.n_pages - 1) - len(self._free)

    @property
    def shared_pages(self) -> int:
        """Physical pages currently referenced by more than one slot."""
        return int(np.sum(self.page_refs > 1))

    def can_reserve(self, n_tokens: int, slot: int | None = None,
                    n_shared: int = 0) -> bool:
        """Can a (possibly partially-grown) slot cover n_tokens total,
        with ``n_shared`` of its pages attached from the prefix cache?"""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:  # reserve() would refuse
            return False
        have = (len(self._owned[slot]) if slot is not None else 0) + n_shared
        return need - have <= len(self._free)

    # -- alloc / free --------------------------------------------------
    def reserve(self, slot: int, n_tokens: int) -> None:
        """Grow `slot` to cover n_tokens logical tokens (idempotent)."""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        owned = self._owned[slot]
        while len(owned) < need:
            if not self._free:
                raise MemoryError(
                    f"page pool exhausted growing slot {slot} to "
                    f"{n_tokens} tokens")
            page = self._free.pop()
            self.page_refs[page] = 1
            self.table[slot, len(owned)] = page
            owned.append(page)

    def release(self, slot: int) -> None:
        """Return `slot`'s page references; free pages that hit refcount 0.

        Releasing a slot that owns nothing is a LOUD error — it means the
        engine double-released or released a slot it never reserved, and
        silently ignoring it would let page-accounting bugs slide until
        two sequences alias the same page.
        """
        if not 0 <= slot < self.max_batch:
            raise ValueError(
                f"release of unknown slot {slot} (max_batch={self.max_batch})")
        owned = self._owned[slot]
        if not owned:
            raise ValueError(
                f"release of slot {slot} which owns no pages "
                "(double release, or a slot that was never reserved)")
        freed: List[int] = []
        for page in owned:
            self.page_refs[page] -= 1
            if self.page_refs[page] == 0:
                for kind, key in self._page_keys.pop(page, ()):
                    (self._prefix if kind == "full" else self._tail).pop(
                        key, None)
                self._pending.discard(page)
                freed.append(page)
        self._free.extend(reversed(freed))
        self._owned[slot] = []
        self.table[slot, :] = TRASH_PAGE

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    # -- copy-on-write prefix sharing ----------------------------------
    def match_prefix(self, prompt: List[int]) -> PrefixMatch:
        """Longest registered prefix of ``prompt`` (capped at len-1).

        Walks the cumulative chain key over full page_size chunks, then
        tries the registered partial tails of the last matched chain node.
        Touching a page whose prefill has not run yet reports
        ``defer=True`` (admit next tick instead of reading unwritten KV).
        """
        ps = self.page_size
        plen = len(prompt)
        if plen <= 1:
            return NO_MATCH
        key = None
        chain: List[int] = []
        for i in range(plen // ps):
            nxt = (key, tuple(prompt[i * ps:(i + 1) * ps]))
            page = self._prefix.get(nxt)
            if page is None:
                break
            if page in self._pending:
                return PrefixMatch(0, (), None, True)
            key = nxt
            chain.append(page)
        raw = len(chain) * ps
        # longest registered boundary entry that prefixes the remainder
        # (valid at ANY chain node: the entry claims rows [0, length) of
        # its page hold the KV of exactly these tokens at these positions)
        rem = prompt[raw:]
        for length in range(min(len(rem), ps - 1), 0, -1):
            hit = self._tail.get((key, tuple(rem[:length])))
            if hit is None:
                continue
            page, rows = hit
            if page in self._pending:
                return PrefixMatch(0, (), None, True)
            chain.append(page)
            raw += rows
            break
        if raw == 0:
            return NO_MATCH
        matched = min(raw, plen - 1)  # always compute >= 1 prompt token
        n_share = matched // ps
        fork = chain[n_share] if matched % ps else None
        return PrefixMatch(matched, tuple(chain[:n_share]), fork, False)

    def reserve_shared(self, slot: int, match: PrefixMatch,
                       n_tokens: int) -> List[Tuple[int, int]]:
        """Reserve `slot` for n_tokens, attaching the matched prefix.

        Shared full pages are aliased (refcount++); a mid-page match
        allocates a private fork page and returns [(src, dst)] so the
        engine can copy the page contents device-side BEFORE prefill.
        The remainder of the reservation comes from the free list.
        """
        if self._owned[slot]:
            raise ValueError(
                f"reserve_shared on slot {slot} which already owns pages")
        if match.defer:
            raise ValueError("cannot reserve a deferred prefix match")
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        if need - len(match.shared) > len(self._free):
            raise MemoryError(
                f"page pool exhausted reserving slot {slot} "
                f"({need} pages, {len(match.shared)} shared)")
        owned = self._owned[slot]
        for page in match.shared:
            self.table[slot, len(owned)] = page
            self.page_refs[page] += 1
            owned.append(page)
        forks: List[Tuple[int, int]] = []
        if match.fork_src is not None:
            dst = self._free.pop()
            self.page_refs[dst] = 1
            self.table[slot, len(owned)] = dst
            owned.append(dst)
            forks.append((match.fork_src, dst))
        self.reserve(slot, n_tokens)
        return forks

    def register_prefix(self, slot: int, prompt: List[int]) -> None:
        """Publish `slot`'s prompt pages into the prefix registry
        (first registration of a key wins — later identical prompts
        alias the original pages).  Entries stay PENDING until
        ``commit_prefixes`` marks this round's prefill done.

        Full pages get one chain key each.  The LAST page additionally
        registers every prefix of its contents as a fork point, so a
        later prompt that shares only the first L rows of that page
        (common system prompt, divergent suffix) can COW-fork it instead
        of losing the whole partial page to recompute.
        """
        ps = self.page_size
        owned = self._owned[slot]
        full, rows = len(prompt) // ps, len(prompt) % ps
        keys = [None]  # chain key after i full pages
        for i in range(full):
            keys.append((keys[i], tuple(prompt[i * ps:(i + 1) * ps])))
            if keys[i + 1] in self._prefix:
                continue
            page = owned[i]
            self._prefix[keys[i + 1]] = page
            self._page_keys.setdefault(page, []).append(("full", keys[i + 1]))
            self._pending.add(page)
        if rows:  # partial tail page: its prefixes, tail length included
            node, start, page = keys[full], full * ps, owned[full]
            lengths = range(1, rows + 1)
        elif full:  # page-aligned prompt: proper prefixes of the last page
            node, start, page = keys[full - 1], (full - 1) * ps, owned[full - 1]
            lengths = range(1, ps)
        else:
            return
        for length in lengths:
            tkey = (node, tuple(prompt[start:start + length]))
            if tkey in self._tail:
                continue
            self._tail[tkey] = (page, length)
            self._page_keys.setdefault(page, []).append(("tail", tkey))
            self._pending.add(page)

    def commit_prefixes(self) -> None:
        """Mark this admission round's registered pages as materialized
        (their batched prefill has been dispatched)."""
        self._pending.clear()
