"""Paged, bit-packed KV cache for the serving engine.

Memory layout (per transformer layer, stacked along a leading `layers` axis
by the model's ``page_specs``):

  * ``kp_pages``: (n_pages, H_kv, page_size, d/32) uint32 — keys bit-packed
    exactly as ``core/binarize`` + ``core/bacam.pack_bits`` produce them
    (the paper's Key SRAM holds binarized keys; 6.25% of the bf16 footprint).
  * ``v_pages``:  (n_pages, H_kv, page_size, d) model dtype — fp16/bf16
    values, gathered sparsely (only the top-k selected rows) at attend time.
  * ``k_scale``:  (max_batch, H_kv) float32 — running per-slot/head key
    scale (softmax temperature bookkeeping; per sequence, not per page).

Sequences own *pages*, not contiguous ``max_len`` spans: a slot's logical
token position ``p`` lives at row ``p % page_size`` of physical page
``page_table[slot, p // page_size]``.  The page table is host-managed by a
free-list allocator and shared by every layer (all layers append in
lockstep, vLLM-style), so continuous batching admits requests whenever
pages — not a whole ``max_len`` slot reservation — are available.

Physical page 0 is reserved as the TRASH page: page-table rows of inactive
or padded slots point at it, so their (masked, never-read) cache writes land
somewhere harmless instead of clobbering live sequences.

Copy-on-write prefix sharing
----------------------------

Pages are REFCOUNTED: several slots may alias one physical page (a shared
system-prompt prefix is prefilled once), and a page returns to the free
list only when its last owner releases it.  Sharing is discovered by
hash-based prefix matching at admission:

  * every admitted prompt registers its full pages under a cumulative
    chain key ``(parent_key, page_tokens)`` and its partial last page (if
    any) under ``(chain_key, tail_tokens)``;
  * ``match_prefix`` walks a new prompt down the chain, collecting the
    longest registered prefix.  Fully-covered pages are attached
    read-only (refcount++).  If the match ends mid-page — the page that
    would receive this request's first KV write — that page is FORKED:
    a fresh physical page is allocated and the engine copies the page's
    contents device-side before prefill (copy-on-write, performed eagerly
    at admission because the write is guaranteed).

Shared pages are never written: a sharer's first computed position is
``matched`` and full shared pages only cover positions below it, while
the mid-page boundary case gets a private fork.  The match is always
capped at ``len(prompt) - 1`` so at least one prompt token is computed
(prefill needs a final hidden state to sample from).

Pages registered in the CURRENT admission round are "pending" — their
contents materialize only when their prefill chunks run — so a prompt
matching a pending page reports ``defer=True`` and the engine retries
next tick (with multi-tick chunked prefill a slot's pages stay pending
until its LAST chunk is dispatched; ``commit_pages`` marks them
materialized per slot).

Prefix retention (LRU)
----------------------

A registered page whose refcount drops to zero is not freed: it moves to
a RETAINED pool (its registry entries stay live), so a drained engine
still hash-matches a resubmitted system prompt and reuses the pages
without re-prefilling.  Retained pages are reclaimable: every allocation
draws from the free list first and then evicts the least-recently-
released retained page (dropping its registry keys).  ``free_pages``
therefore counts free + retained — both are available capacity — while
``retained_pages`` exposes the cache depth.  Plan/commit split: all of
this is host-pure bookkeeping; the engine snapshots the page table at
dispatch time, so host-side reservations and evictions never perturb
ticks already in flight (device content of a retained page stays valid
until a later prefill/fork overwrites it, which the dispatch order
guarantees happens only after any copy that still reads it).
"""

from __future__ import annotations

import dataclasses
from collections import Counter, OrderedDict
from typing import Dict, Iterable, List, NamedTuple, Optional, Set, Tuple

import numpy as np

from repro.serving.faults import FaultPlan, NO_FAULTS

__all__ = ["PagedKVCache", "PrefixMatch", "NO_MATCH", "TRASH_PAGE",
           "pages_for"]

TRASH_PAGE = 0  # physical page 0 is never allocated


def pages_for(n_tokens: int, page_size: int) -> int:
    """Number of pages needed to hold n_tokens."""
    return -(-max(n_tokens, 0) // page_size)


class PrefixMatch(NamedTuple):
    """Result of hash-matching a prompt against the registered prefixes."""

    matched: int  # tokens covered by shared pages (+ fork), < len(prompt)
    shared: Tuple[int, ...]  # full pages attached read-only (refcount++)
    fork_src: Optional[int]  # page to copy-on-write fork, or None
    defer: bool  # prefix registered this tick but not yet prefilled


NO_MATCH = PrefixMatch(0, (), None, False)


@dataclasses.dataclass
class PagedKVCache:
    """Host-side page-table + free-list allocator over the device pools.

    The device-side pools themselves live with the engine (they are jitted
    function state); this object owns which physical page belongs to which
    slot, hands out / reclaims pages, and tracks refcounts + the prefix
    registry for copy-on-write page sharing.
    """

    n_pages: int
    page_size: int
    max_batch: int
    max_pages_per_seq: int
    retain_prefixes: bool = True  # LRU-cache refcount-0 registered pages
    # chaos hook: while `kv.exhaust` is armed the allocator reports an
    # empty pool (level-triggered so capacity checks and allocations
    # agree within a tick).  NO_FAULTS in production.
    faults: FaultPlan = NO_FAULTS

    def __post_init__(self):
        if self.n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        # LIFO free list; page 0 reserved as the trash page.
        self._free: List[int] = list(range(self.n_pages - 1, TRASH_PAGE, -1))
        # refcount-0 pages kept for prefix reuse, LRU order (oldest first).
        self._retained: "OrderedDict[int, None]" = OrderedDict()
        self._owned: List[List[int]] = [[] for _ in range(self.max_batch)]
        self.table = np.full((self.max_batch, self.max_pages_per_seq),
                             TRASH_PAGE, np.int32)
        # page_refs[p] == number of slots whose page table references p;
        # 0 <=> p is free (or the trash page).
        self.page_refs = np.zeros(self.n_pages, np.int32)
        # prefix registry: chain key -> page (full pages), and
        # (chain key, tail tokens) -> (page, rows) for a partial last page.
        self._prefix: Dict[tuple, int] = {}
        self._tail: Dict[tuple, Tuple[int, int]] = {}
        self._page_keys: Dict[int, List[tuple]] = {}  # page -> registry keys
        self._pending: Set[int] = set()  # registered, not yet prefilled

    # -- capacity ------------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Reclaimable pages: truly free + retained (evictable) prefixes."""
        return len(self._free) + len(self._retained)

    @property
    def retained_pages(self) -> int:
        """Refcount-0 pages kept alive for prefix reuse (LRU-evictable)."""
        return len(self._retained)

    @property
    def used_pages(self) -> int:
        """UNIQUE physical pages actively owned (shared pages count once;
        retained prefix pages do not count — they are reclaimable)."""
        return (self.n_pages - 1) - self.free_pages

    @property
    def shared_pages(self) -> int:
        """Physical pages currently referenced by more than one slot."""
        return int(np.sum(self.page_refs > 1))

    def occupancy(self, tp: int = 1) -> dict:
        """Page-pool occupancy snapshot, broken out per device for the
        gateway's ``GET /metrics``.

        There is ONE host page table regardless of the tensor-parallel
        degree: under head-sharded serving (serving/sharded.py) every
        device holds ALL pages — each carrying 1/tp of the page's
        kv-head slice — so per-device page occupancy is the allocator's
        global view replicated ``tp`` ways.  Reporting it per device id
        keeps dashboards keyed by device uniform as ``tp`` changes."""
        pool = self.n_pages - 1  # physical pages minus the trash page
        used = self.used_pages
        frac = used / max(pool, 1)
        return {
            "tp": tp,
            "pool_pages": pool,
            "used_pages": used,
            "retained_pages": self.retained_pages,
            "shared_pages": self.shared_pages,
            "per_device": [
                {"device": d, "used_pages": used, "pool_pages": pool,
                 "occupancy": frac}
                for d in range(tp)
            ],
        }

    def _avail_for(self, match: "PrefixMatch" = NO_MATCH) -> int:
        """Pages allocatable while attaching `match`: attached shared
        pages leave the retained pool without consuming an allocation,
        and the fork source is pinned against eviction for the fork
        copy."""
        if self.faults.active("kv.exhaust"):
            return 0
        avail = self.free_pages
        avail -= sum(1 for p in match.shared if p in self._retained)
        if match.fork_src is not None and match.fork_src in self._retained:
            avail -= 1
        return avail

    def can_reserve(self, n_tokens: int, slot: int | None = None,
                    n_shared: int = 0,
                    match: "PrefixMatch" = NO_MATCH) -> bool:
        """Can a (possibly partially-grown) slot cover n_tokens total,
        with ``n_shared`` of its pages attached from the prefix cache?"""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:  # reserve() would refuse
            return False
        have = (len(self._owned[slot]) if slot is not None else 0) + n_shared
        return need - have <= self._avail_for(match)

    # -- alloc / free --------------------------------------------------
    def _alloc_page(self, avoid: Tuple[int, ...] = ()) -> Optional[int]:
        """One page off the free list, else evict the LRU retained prefix
        page (its registry entries are dropped).  ``avoid`` pins pages
        that must survive this allocation (a pending fork source).
        Returns None when nothing is reclaimable."""
        if self.faults.active("kv.exhaust"):
            return None
        if self._free:
            return self._free.pop()
        for page in self._retained:
            if page not in avoid:
                del self._retained[page]
                for kind, key in self._page_keys.pop(page, ()):
                    (self._prefix if kind == "full" else self._tail).pop(
                        key, None)
                return page
        return None

    def reserve(self, slot: int, n_tokens: int) -> None:
        """Grow `slot` to cover n_tokens logical tokens (idempotent)."""
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        owned = self._owned[slot]
        while len(owned) < need:
            page = self._alloc_page()
            if page is None:
                raise MemoryError(
                    f"page pool exhausted growing slot {slot} to "
                    f"{n_tokens} tokens")
            self.page_refs[page] = 1
            self.table[slot, len(owned)] = page
            owned.append(page)

    def release(self, slot: int) -> None:
        """Return `slot`'s page references; free pages that hit refcount 0.

        Releasing a slot that owns nothing is a LOUD error — it means the
        engine double-released or released a slot it never reserved, and
        silently ignoring it would let page-accounting bugs slide until
        two sequences alias the same page.
        """
        if not 0 <= slot < self.max_batch:
            raise ValueError(
                f"release of unknown slot {slot} (max_batch={self.max_batch})")
        owned = self._owned[slot]
        if not owned:
            raise ValueError(
                f"release of slot {slot} which owns no pages "
                "(double release, or a slot that was never reserved)")
        freed: List[int] = []
        for page in owned:
            self.page_refs[page] -= 1
            if self.page_refs[page] == 0:
                # materialized registered pages are RETAINED (LRU) so the
                # prefix stays matchable after its last owner drains;
                # pending pages (prefill never completed) and unregistered
                # pages go straight back to the free list.
                if (self.retain_prefixes and page in self._page_keys
                        and page not in self._pending):
                    self._retained[page] = None  # newest end of the LRU
                    continue
                for kind, key in self._page_keys.pop(page, ()):
                    (self._prefix if kind == "full" else self._tail).pop(
                        key, None)
                self._pending.discard(page)
                freed.append(page)
        self._free.extend(reversed(freed))
        self._owned[slot] = []
        self.table[slot, :] = TRASH_PAGE

    def owned(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def truncate_to(self, slot: int, length: int) -> List[Tuple[int, int]]:
        """Shrink `slot` to its first `length` logical tokens (speculative-
        decode rollback).  Pages wholly beyond the keep point are released
        exactly like ``release`` (refcount--, retained-or-freed, registry
        entries of freed pages dropped).  The BOUNDARY page — the partial
        page that will receive the slot's next write at row
        ``length % page_size`` — must never be written while shared: if it
        is COW-aliased it is forked (a fresh private page, returned as an
        ``[(src, dst)]`` copy job for the engine's device-side page copy)
        or the call refuses with MemoryError when the pool cannot supply
        the fork page.  Registry entries claiming rows of the kept
        boundary page beyond the keep point are dropped (the slot is about
        to rewrite those rows with different tokens), which keeps
        hash-matching sound after rollback.  Idempotent: truncating twice
        to the same length is a no-op the second time.
        """
        if not 0 <= slot < self.max_batch:
            raise ValueError(
                f"truncate of unknown slot {slot} "
                f"(max_batch={self.max_batch})")
        if length < 0:
            raise ValueError(f"cannot truncate slot {slot} to {length}")
        ps = self.page_size
        owned = self._owned[slot]
        keep = pages_for(length, ps)
        # -- release pages wholly beyond the keep point (release() logic)
        freed: List[int] = []
        for page in owned[keep:]:
            self.page_refs[page] -= 1
            if self.page_refs[page] == 0:
                if (self.retain_prefixes and page in self._page_keys
                        and page not in self._pending):
                    self._retained[page] = None
                    continue
                for kind, key in self._page_keys.pop(page, ()):
                    (self._prefix if kind == "full" else self._tail).pop(
                        key, None)
                self._pending.discard(page)
                freed.append(page)
        self._free.extend(reversed(freed))
        del owned[keep:]
        self.table[slot, keep:] = TRASH_PAGE
        # -- boundary page: kept partially, rewritten from row length%ps
        rows_kept = length % ps
        if not rows_kept or keep - 1 >= len(owned):
            return []
        src = owned[keep - 1]
        forks: List[Tuple[int, int]] = []
        if self.page_refs[src] > 1:
            # never write a shared page: fork it (or refuse).  The source
            # keeps its registry entries and its other owners.
            dst = self._alloc_page(avoid=(src,))
            if dst is None:
                raise MemoryError(
                    f"page pool exhausted forking shared boundary page "
                    f"{src} truncating slot {slot} to {length} tokens")
            self.page_refs[dst] = 1
            self.page_refs[src] -= 1
            owned[keep - 1] = dst
            self.table[slot, keep - 1] = dst
            forks.append((src, dst))
        else:
            # private boundary page: registry claims over rows the slot is
            # about to rewrite are now stale — drop them.
            survivors: List[tuple] = []
            for kind, key in self._page_keys.get(src, ()):
                stale = (kind == "full"
                         or self._tail.get(key, (None, 0))[1] > rows_kept)
                if stale:
                    (self._prefix if kind == "full" else self._tail).pop(
                        key, None)
                else:
                    survivors.append((kind, key))
            if src in self._page_keys:
                if survivors:
                    self._page_keys[src] = survivors
                else:
                    del self._page_keys[src]
                    self._pending.discard(src)
        return forks

    # -- copy-on-write prefix sharing ----------------------------------
    def match_prefix(self, prompt: List[int]) -> PrefixMatch:
        """Longest registered prefix of ``prompt`` (capped at len-1).

        Walks the cumulative chain key over full page_size chunks, then
        tries the registered partial tails of the last matched chain node.
        Touching a page whose prefill has not run yet reports
        ``defer=True`` (admit next tick instead of reading unwritten KV).
        """
        ps = self.page_size
        plen = len(prompt)
        if plen <= 1:
            return NO_MATCH
        key = None
        chain: List[int] = []
        for i in range(plen // ps):
            nxt = (key, tuple(prompt[i * ps:(i + 1) * ps]))
            page = self._prefix.get(nxt)
            if page is None:
                break
            if page in self._pending:
                return PrefixMatch(0, (), None, True)
            key = nxt
            chain.append(page)
        raw = len(chain) * ps
        # longest registered boundary entry that prefixes the remainder
        # (valid at ANY chain node: the entry claims rows [0, length) of
        # its page hold the KV of exactly these tokens at these positions)
        rem = prompt[raw:]
        for length in range(min(len(rem), ps - 1), 0, -1):
            hit = self._tail.get((key, tuple(rem[:length])))
            if hit is None:
                continue
            page, rows = hit
            if page in self._pending:
                return PrefixMatch(0, (), None, True)
            chain.append(page)
            raw += rows
            break
        if raw == 0:
            return NO_MATCH
        matched = min(raw, plen - 1)  # always compute >= 1 prompt token
        n_share = matched // ps
        fork = chain[n_share] if matched % ps else None
        return PrefixMatch(matched, tuple(chain[:n_share]), fork, False)

    def reserve_shared(self, slot: int, match: PrefixMatch,
                       n_tokens: int) -> List[Tuple[int, int]]:
        """Reserve `slot` for n_tokens, attaching the matched prefix.

        Shared full pages are aliased (refcount++); a mid-page match
        allocates a private fork page and returns [(src, dst)] so the
        engine can copy the page contents device-side BEFORE prefill.
        The remainder of the reservation comes from the free list.
        """
        if self._owned[slot]:
            raise ValueError(
                f"reserve_shared on slot {slot} which already owns pages")
        if match.defer:
            raise ValueError("cannot reserve a deferred prefix match")
        need = pages_for(n_tokens, self.page_size)
        if need > self.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs {need} pages > "
                f"max_pages_per_seq={self.max_pages_per_seq}")
        if need - len(match.shared) > self._avail_for(match):
            raise MemoryError(
                f"page pool exhausted reserving slot {slot} "
                f"({need} pages, {len(match.shared)} shared)")
        owned = self._owned[slot]
        for page in match.shared:
            self._retained.pop(page, None)  # revive a drained prefix page
            self.table[slot, len(owned)] = page
            self.page_refs[page] += 1
            owned.append(page)
        forks: List[Tuple[int, int]] = []
        if match.fork_src is not None:
            # the fork source must survive until the engine's device copy
            # runs; pin it against LRU eviction for the dst allocation
            dst = self._alloc_page(avoid=(match.fork_src,))
            assert dst is not None  # _avail_for accounted for the pin
            self.page_refs[dst] = 1
            self.table[slot, len(owned)] = dst
            owned.append(dst)
            forks.append((match.fork_src, dst))
        self.reserve(slot, n_tokens)
        return forks

    def register_prefix(self, slot: int, prompt: List[int]) -> None:
        """Publish `slot`'s prompt pages into the prefix registry
        (first registration of a key wins — later identical prompts
        alias the original pages).  Entries stay PENDING until
        ``commit_prefixes`` marks this round's prefill done.

        Full pages get one chain key each.  The LAST page additionally
        registers every prefix of its contents as a fork point, so a
        later prompt that shares only the first L rows of that page
        (common system prompt, divergent suffix) can COW-fork it instead
        of losing the whole partial page to recompute.
        """
        ps = self.page_size
        owned = self._owned[slot]
        full, rows = len(prompt) // ps, len(prompt) % ps
        keys = [None]  # chain key after i full pages
        for i in range(full):
            keys.append((keys[i], tuple(prompt[i * ps:(i + 1) * ps])))
            if keys[i + 1] in self._prefix:
                continue
            page = owned[i]
            self._prefix[keys[i + 1]] = page
            self._page_keys.setdefault(page, []).append(("full", keys[i + 1]))
            self._pending.add(page)
        if rows:  # partial tail page: its prefixes, tail length included
            node, start, page = keys[full], full * ps, owned[full]
            lengths = range(1, rows + 1)
        elif full:  # page-aligned prompt: proper prefixes of the last page
            node, start, page = keys[full - 1], (full - 1) * ps, owned[full - 1]
            lengths = range(1, ps)
        else:
            return
        for length in lengths:
            tkey = (node, tuple(prompt[start:start + length]))
            if tkey in self._tail:
                continue
            self._tail[tkey] = (page, length)
            self._page_keys.setdefault(page, []).append(("tail", tkey))
            self._pending.add(page)

    def commit_pages(self, pages: Iterable[int]) -> None:
        """Mark `pages` as materialized (their prefill chunks have all
        been dispatched).  With multi-tick chunked prefill each slot
        commits its own pages when its LAST chunk is planned; other
        slots' mid-prefill pages stay pending (and defer matches)."""
        for p in pages:
            self._pending.discard(p)

    def commit_prefixes(self) -> None:
        """Mark EVERY registered page as materialized (single-dispatch
        prefill callers; per-slot callers use ``commit_pages``)."""
        self._pending.clear()

    # -- invariants (chaos harness / crash containment) ----------------
    def invalidate(self, slot: int) -> None:
        """Poison-pill `slot`'s exclusively-owned pages before a crash-
        containment release: a failed device tick may have written
        garbage into them, so their prefix-registry claims are dropped —
        they free instead of retaining, and no later prompt hash-matches
        content that never materialized.  Pages shared with other slots
        (refcount > 1) keep their entries: shared pages are never
        written, so their contents predate the failed tick and stay
        valid for the surviving owners."""
        for page in self._owned[slot]:
            if self.page_refs[page] > 1:
                continue
            for kind, key in self._page_keys.pop(page, ()):
                (self._prefix if kind == "full" else self._tail).pop(key, None)
            self._pending.discard(page)

    def check(self) -> bool:
        """Audit the allocator's standing invariants; AssertionError on
        the first violation, True when the pool balances.  Cheap enough
        to call after every tick in the chaos tests:

          * free + retained + used == n_pages - 1, with the three sets
            pairwise disjoint and the trash page in none of them;
          * ``page_refs[p]`` equals the number of slots owning ``p``
            (so free/retained pages have refcount 0);
          * each slot's page-table row mirrors its owned list (trash
            beyond it);
          * every registry-claimed page is live (owned or retained) and
            every prefix/tail entry's page carries the matching claim.
        """
        errors: List[str] = []
        owned_all = [p for pages in self._owned for p in pages]
        owned, free, retained = (set(owned_all), set(self._free),
                                 set(self._retained))
        if len(free) != len(self._free):
            errors.append("duplicate pages on the free list")
        for name, pages in (("owned", owned), ("free", free),
                            ("retained", retained)):
            if TRASH_PAGE in pages:
                errors.append(f"trash page in {name} set")
            bad = [p for p in pages if not 0 < p < self.n_pages]
            if bad:
                errors.append(f"{name} pages out of range: {bad}")
        for a, b in (("owned", "free"), ("owned", "retained"),
                     ("free", "retained")):
            inter = {"owned": owned, "free": free,
                     "retained": retained}[a] & {
                         "owned": owned, "free": free, "retained": retained}[b]
            if inter:
                errors.append(f"{a}/{b} overlap: {sorted(inter)}")
        total = len(owned) + len(free) + len(retained)
        if total != self.n_pages - 1:
            errors.append(
                f"accounting: used {len(owned)} + free {len(free)} + "
                f"retained {len(retained)} != pool {self.n_pages - 1}")
        refs = Counter(owned_all)
        for p in range(1, self.n_pages):
            if self.page_refs[p] != refs.get(p, 0):
                errors.append(
                    f"page {p}: refcount {int(self.page_refs[p])} != "
                    f"{refs.get(p, 0)} owning slots")
        if self.page_refs[TRASH_PAGE] != 0:
            errors.append("trash page has nonzero refcount")
        for slot, pages in enumerate(self._owned):
            row = self.table[slot]
            if (list(row[:len(pages)]) != pages
                    or any(row[len(pages):] != TRASH_PAGE)):
                errors.append(
                    f"slot {slot}: table row {row.tolist()} does not "
                    f"mirror owned {pages}")
        live = owned | retained
        for page, keys in self._page_keys.items():
            if page not in live:
                errors.append(f"registry claims dead page {page}")
            for kind, key in keys:
                reg = self._prefix if kind == "full" else self._tail
                val = reg.get(key)
                got = val if kind == "full" else (val and val[0])
                if got != page:
                    errors.append(
                        f"page {page}: stale {kind} claim {key!r} -> {val!r}")
        for key, page in self._prefix.items():
            if ("full", key) not in self._page_keys.get(page, ()):
                errors.append(f"prefix entry {key!r} unclaimed by page {page}")
        for key, (page, _) in self._tail.items():
            if ("tail", key) not in self._page_keys.get(page, ()):
                errors.append(f"tail entry {key!r} unclaimed by page {page}")
        if self._pending - set(self._page_keys):
            errors.append(
                f"pending pages without registry claims: "
                f"{sorted(self._pending - set(self._page_keys))}")
        if errors:
            raise AssertionError("PagedKVCache.check: " + "; ".join(errors))
        return True
