"""CAMformer core: the paper's contribution as composable JAX modules."""

from repro.core.attention import AttentionSpec, attention, dense_reference, make_mask
from repro.core.bacam import (
    CAM_H,
    CAM_W,
    bacam_scores,
    binary_scores_exact,
    hamming_scores_packed,
    pack_bits,
    unpack_bits,
)
from repro.core.binarize import binarize_qk, had_scales, sign_pm1, sign_ste
from repro.core.topk import (
    NEG_INF,
    hoeffding_drop_bound,
    single_stage_topk,
    topk_recall,
    two_stage_topk,
)

__all__ = [
    "AttentionSpec", "attention", "dense_reference", "make_mask",
    "CAM_H", "CAM_W", "bacam_scores", "binary_scores_exact",
    "hamming_scores_packed", "pack_bits", "unpack_bits",
    "binarize_qk", "had_scales", "sign_pm1", "sign_ste",
    "NEG_INF", "hoeffding_drop_bound", "single_stage_topk",
    "topk_recall", "two_stage_topk",
]
