"""BA-CAM device model: binary attention-score computation.

This module is the *functional* model of the paper's Binary Attention CAM
(Sec. II): a CAM array stores binary keys, a binary query is broadcast, each
matching bit adds charge to the matchline, and the matchline voltage —
linearly proportional to Hamming similarity — is digitized by a shared 6-bit
SAR ADC and mapped to a signed score ``s = 2*ADC(v) - CAM_W`` in [-64, 64]
(for d_k = 64).

TPU-native adaptation (see DESIGN.md §2): sign bits are packed 32-per-uint32
word and the matchline accumulate becomes XNOR + ``lax.population_count``.
For ±1 vectors the *ideal* matchline result equals the integer dot product:

    dot(qb, kb) = matches - mismatches = 2*matches - d = d - 2*popcount(q^k)

so the exact-integer path used in compute is bit-identical to an ideal
(noise-free, full-precision-ADC) BA-CAM.  The optional device-fidelity knobs
(``adc_bits``, ``noise_sigma``) model the analog non-idealities the paper
characterizes (6-bit SAR quantization, sigma = 1.4% matchline deviation,
Fig. 3b) and are used by the fidelity benchmarks, not the training path.

Vertical tiling (d_k > CAM_W) follows Fig. 4: per-tile analog match counts
are digitized *per tile* and accumulated digitally in the accumulation
register — so quantization error enters per CAM_W-wide tile, which the device
model reproduces exactly.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = [
    "CAM_W",
    "CAM_H",
    "pack_bits",
    "unpack_bits",
    "hamming_scores_packed",
    "binary_scores_exact",
    "adc_readout",
    "bacam_scores",
]

# Paper's array geometry (Sec. III-B1): 16 keys x 64 bits per BA-CAM tile.
CAM_W = 64  # bits per row == matchline width (d_k tile)
CAM_H = 16  # keys per array (stage-1 top-2 group size)


def pack_bits(x: jax.Array) -> jax.Array:
    """Pack the sign bits of ``x`` (..., d) into uint32 words (..., d//32).

    Bit j of word w is 1 iff x[..., 32*w + j] > 0.  d must be a multiple of
    32 (all assigned head dims are 64/128/256).
    """
    *lead, d = x.shape
    if d % 32 != 0:
        raise ValueError(f"packing requires d % 32 == 0, got d={d}")
    bits = (x > 0).astype(jnp.uint32).reshape(*lead, d // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    # Shifted bits occupy disjoint positions; sum == bitwise OR.
    return (bits << shifts).sum(axis=-1).astype(jnp.uint32)


def unpack_bits(packed: jax.Array, d: int) -> jax.Array:
    """Inverse of :func:`pack_bits` into {-1,+1} float32 (..., d)."""
    *lead, w = packed.shape
    if w * 32 != d:
        raise ValueError(f"packed width {w} inconsistent with d={d}")
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[..., None] >> shifts) & jnp.uint32(1)
    return (bits.reshape(*lead, d).astype(jnp.float32) * 2.0 - 1.0)


def hamming_scores_packed(q_packed: jax.Array, k_packed: jax.Array, d: int) -> jax.Array:
    """Signed binary scores from packed operands.

    Args:
      q_packed: (..., Sq, W) uint32.
      k_packed: (..., Sk, W) uint32 (same leading dims).
      d: original bit width (W = d // 32).

    Returns:
      (..., Sq, Sk) int32 scores in [-d, d]:  s = d - 2*popcount(q ^ k).
    """
    x = jnp.bitwise_xor(q_packed[..., :, None, :], k_packed[..., None, :, :])
    mismatches = jax.lax.population_count(x).astype(jnp.int32).sum(axis=-1)
    return jnp.int32(d) - 2 * mismatches


def binary_scores_exact(qb: jax.Array, kb: jax.Array) -> jax.Array:
    """Oracle: signed scores as a plain ±1 matmul, s = qb . kb (..., Sq, Sk)."""
    return jnp.einsum("...qd,...kd->...qk", qb, kb)


def adc_readout(match_count: jax.Array, *, cam_w: int = CAM_W, bits: int = 6) -> jax.Array:
    """Model the 6-bit SAR ADC digitizing one matchline.

    The matchline voltage is v = match_count / cam_w in [0, 1] (linear charge
    sharing).  The ADC produces code = round(v * (2^bits - 1)); the digital
    reconstruction is count_hat = code * cam_w / (2^bits - 1).

    For cam_w = 64, bits = 6 the step is 64/63 ~ 1.016 counts: sub-LSB error
    (the paper's "ADC precision covers the full match range"); bits >= 7 is
    exact.  Returned as float32 counts.
    """
    levels = (1 << bits) - 1
    v = match_count.astype(jnp.float32) / float(cam_w)
    code = jnp.clip(jnp.round(v * levels), 0, levels)
    # The accumulation register reconstructs integer match counts digitally.
    return jnp.round(code * (float(cam_w) / levels))


@partial(jax.jit, static_argnames=("cam_w", "adc_bits", "exact", "noise_sigma"))
def bacam_scores(
    qb: jax.Array,
    kb: jax.Array,
    *,
    cam_w: int = CAM_W,
    adc_bits: int | None = None,
    noise_sigma: float = 0.0,
    rng: jax.Array | None = None,
    exact: bool = True,
) -> jax.Array:
    """Full BA-CAM device model for binary QK^T.

    Args:
      qb, kb: ±1 tensors (..., Sq, d) / (..., Sk, d); d % cam_w == 0
        (vertical tiling per Fig. 4 when d > cam_w).
      cam_w: matchline width (bits digitized per ADC conversion).
      adc_bits: ADC resolution; ``None`` or ``exact=True`` uses the ideal
        integer path (bit-identical for d_k<=64 @ >=7 bits).
      noise_sigma: relative matchline-voltage noise (paper: 1.4% => near-
        lossless, Fig. 3b / Table I footnote).  Requires ``rng``.
      exact: shortcut to the exact integer path (the compute/training path).

    Returns:
      (..., Sq, Sk) float32 (device model) or int32 (exact) scores in [-d, d].
    """
    d = qb.shape[-1]
    if exact and adc_bits is None and noise_sigma == 0.0:
        qp, kp = pack_bits(qb), pack_bits(kb)
        return hamming_scores_packed(qp, kp, d)

    if d % cam_w != 0:
        raise ValueError(f"d={d} must tile by cam_w={cam_w}")
    n_tiles = d // cam_w
    qt = qb.reshape(*qb.shape[:-1], n_tiles, cam_w)
    kt = kb.reshape(*kb.shape[:-1], n_tiles, cam_w)
    # matches per vertical tile: (d + dot)/2 restricted to the tile.
    # (einsum ellipsis broadcasting handles GQA's inserted group axis)
    dots = jnp.einsum("...qtc,...ktc->...qkt", qt, kt)
    matches = (dots + cam_w) * 0.5  # in [0, cam_w]
    if noise_sigma > 0.0:
        if rng is None:
            raise ValueError("noise_sigma > 0 requires rng")
        matches = matches + noise_sigma * cam_w * jax.random.normal(
            rng, matches.shape, dtype=jnp.float32
        )
        matches = jnp.clip(matches, 0.0, float(cam_w))
    if adc_bits is not None:
        matches = adc_readout(matches, cam_w=cam_w, bits=adc_bits)
    # Signed mapping s = 2*count - cam_w, accumulated digitally across tiles.
    return (2.0 * matches - cam_w).sum(axis=-1)
