"""Pluggable attention backends: one protocol for dense / binary / CAM.

The paper's thesis is that attention is an associative-memory *operation*
with interchangeable physical realizations (BA-CAM voltage-domain search
vs. digital arithmetic, Sec. III).  This module is that seam in code: an
``AttentionBackend`` defines how one attention layer realizes

  * its **contiguous KV cache** layout (``cache_spec``) and the decode
    step against it (``decode``),
  * its **paged KV pool** layout (``page_spec``) and the paged
    prefill/decode step against it (``paged_decode`` — the single
    serving-engine path),
  * plain attention over freshly computed K/V (``prefill`` — training,
    whole-prompt prefill, and cross-attention).

Concrete backends (registered at import, mirroring ``models/registry.py``):

  * ``dense``     — standard softmax attention; bf16 K/V caches & pages.
  * ``binary``    — HAD-binarized scoring, full softmax; dense storage
                    (keys are binarized at attend time, the ablation
                    ladder's single-stage upper bound).  Paged pools add
                    a running per-slot ``k_scale`` so the HAD softmax
                    temperature streams (no gathered-key recompute).
  * ``camformer`` — the paper: bit-packed binary Key SRAM (6.25% of bf16),
                    two-stage top-k CAM search, sparse top-k V gather;
                    fused Pallas kernels on the decode hot paths.

Every backend's ``paged_decode`` has TWO selectable realizations
(``ModelConfig.paged_impl``): ``"fused"`` (default) runs the decode row
through a Pallas paged kernel — the flash-decode skeleton
(kernels/paged_flash_decode.py) for dense/binary, the CAM search kernel
(kernels/bacam_decode.py) for camformer — walking the slot's page list
via scalar-prefetched page-table rows with a streaming softmax, so
decode reads are proportional to LIVE pages; ``"gather"`` keeps the XLA
page-gather + masked attend as the reference oracle every kernel claim
is pinned against (``kernels/ref.paged_gather_ref``).  Sq > 1 chunk
rows (chunked prefill and speculative verify) run the SAME flash
skeleton with per-row causal anchors for dense/binary
(``ModelConfig.prefill_impl``: "auto" follows paged_impl); camformer
chunks still gather — there is no fused Sq>1 CAM kernel yet.  The
``hybrid`` backend closes that gap structurally: flash-scored fused
prefill chunks over a dense key pool + CAM paged decode.

Per-layer policy lives on ``ModelConfig`` (``attn_backend`` +
``layer_backends``; ``cfg.backend_for(layer)`` resolves a name) so hybrid
models can run, e.g., sliding-window layers on ``dense`` and
full-attention layers on ``camformer`` — the mixed-tile regime of
X-Former-style accelerators.  New realizations are a ``register_backend``
call, not another ``if``-ladder site.

Fused-step contract (the overlapped serving engine): ``paged_decode`` is
dispatched once per engine tick for EVERY batch row inside one jit —
decode rows, chunked-prefill rows, and inert rows alike — with sampling
fused behind it, so the sampled token ids are the tick's only
host<->device readback.  That imposes two row-level requirements on
every backend:

  * rows are independent: one row's inputs never change another row's
    outputs or cache state (attention is per-row by construction; the
    only known coupling is MoE capacity routing, which serving configs
    must treat as approximate under overlap);
  * ``kv_len == 0`` marks an INERT row: its page writes must resolve to
    the trash page and its per-slot running statistics (camformer's
    ``k_scale``) must be left untouched, so the engine can carry
    preempted/finished/mid-prefill slots through a tick without
    corrupting them.
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import bacam
from repro.core.attention import (AttentionSpec, attention,
                                  binary_paged_attention,
                                  camformer_paged_attention,
                                  topk_softmax_weights)
from repro.core.binarize import sign_pm1
from repro.core.topk import NEG_INF, two_stage_topk
from repro.utils import compat

__all__ = [
    "AttentionBackend", "DenseBackend", "BinaryBackend", "CamformerBackend",
    "HybridBackend",
    "register_backend", "get_backend", "list_backends", "backends_for",
]


# ---------------------------------------------------------------------------
# registry

_BACKENDS: Dict[str, "AttentionBackend"] = {}


def register_backend(backend: "AttentionBackend") -> "AttentionBackend":
    """Register a backend instance under ``backend.name`` (last wins)."""
    _BACKENDS[backend.name] = backend
    return backend


def get_backend(name: str) -> "AttentionBackend":
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"unknown attention backend {name!r}; have {sorted(_BACKENDS)}")


def list_backends() -> list:
    return sorted(_BACKENDS)


def backends_for(cfg) -> tuple:
    """Resolve the per-layer backend objects for a model config."""
    return tuple(get_backend(cfg.backend_for(i)) for i in range(cfg.n_layers))


# ---------------------------------------------------------------------------
# shared cache plumbing


def _seq_insert(buf, upd, index):
    """Insert `upd` into `buf` along axis 2 (cache seq).

    index: scalar — uniform write (train/prefill/dry-run decode);
           (B,) array — ragged per-slot write (continuous batching).
    """
    zero = jnp.zeros((), jnp.int32)
    if jnp.ndim(index) == 0:
        return jax.lax.dynamic_update_slice(buf, upd, (zero, zero, index, zero))
    one = lambda b, u, i: jax.lax.dynamic_update_slice(b, u, (zero, i, zero))
    return jax.vmap(one)(buf, upd, index.astype(jnp.int32))


_TRASH_PAGE = 0  # serving/kv_cache.py contract: physical page 0 is trash


def _running_k_scale(k_scale, k, pos, kv_len, base):
    """Update a slot's running per-head key scale over VALID tokens only.

    k_scale: (B, H_kv) stored running mean of mean_d(|k|); k: (B, H_kv,
    S, D) the freshly written keys; pos: (B, S) their logical positions;
    kv_len: (B,) valid tokens INCLUDING this write; base: (B,) or None —
    the prefix-sharing offset below which positions live in ANOTHER
    slot's shared pages (they never counted toward this slot's mean).
    Rows with no valid tokens (kv_len == 0 inert rows, fully-padded
    chunks) leave the stored scale untouched — the fused-step contract.
    """
    b = k.shape[0]
    valid = (pos < kv_len[:, None]).astype(jnp.float32)  # (B, S)
    mean_d = jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=3)  # (B,Hkv,S)
    new_sum = jnp.einsum("bhs,bs->bh", mean_d, valid)
    cnt = jnp.sum(valid, axis=-1)  # (B,)
    if base is None:
        base = jnp.zeros((b,), jnp.int32)
    prior = jnp.clip(jnp.minimum(pos[:, 0], kv_len)
                     - base.reshape(b).astype(jnp.int32),
                     0, None).astype(jnp.float32)
    total = prior + cnt
    ks = ((k_scale * prior[:, None] + new_sum)
          / jnp.maximum(total, 1.0)[:, None])
    return jnp.where((total > 0)[:, None], ks, k_scale)


def _chunk_scale_seq(k_scale, k, pos, kv_len, base):
    """Per-query running key scales for a speculative verify chunk.

    The stored ``k_scale`` is one value per slot, which is correct for a
    single-token decode step but not for an Sq>1 verify chunk: the query
    at chunk column j must see the running mean over keys up to ITS OWN
    position (``[base, pos+j]``) — the value the sequential decode loop
    would have used — not a mean contaminated by the chunk's later keys.

    Returns ``(per_query (B, H_kv, S), means (B, H_kv, S))`` — the
    sequential-semantics scale per query column, and the chunk's
    per-position valid-masked ``mean_d(|k|)`` (stashed in the ``k_means``
    pool leaf so the host-planned rollback can rebuild the running mean
    at ANY accepted length exactly; see serving/speculate.py).
    """
    b = k.shape[0]
    valid = (pos < kv_len[:, None]).astype(jnp.float32)  # (B, S)
    means = (jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=3)
             * valid[:, None, :])  # (B, Hkv, S)
    if base is None:
        base = jnp.zeros((b,), jnp.int32)
    prior = jnp.clip(jnp.minimum(pos[:, 0], kv_len)
                     - base.reshape(b).astype(jnp.int32),
                     0, None).astype(jnp.float32)  # (B,)
    cum = jnp.cumsum(means, axis=2)
    cnt = prior[:, None] + jnp.cumsum(valid, axis=1)  # (B, S)
    per_q = ((k_scale[:, :, None] * prior[:, None, None] + cum)
             / jnp.maximum(cnt, 1.0)[:, None, :])
    per_q = jnp.where((cnt > 0)[:, None, :], per_q, k_scale[:, :, None])
    return per_q, means


def _page_phys_rows(page_table, positions, page: int, kv_len=None):
    """(physical page, in-page row) of each logical position. Both (B, S).

    With kv_len (B,), positions >= kv_len (right-padding rows of a
    batched prefill) resolve to the TRASH page: their logical positions
    can exceed the slot's page-table extent (prefix-sharing offsets push
    padding past max_len), where jnp's clamped indexing would otherwise
    alias them onto the slot's last page and corrupt live rows.
    """
    b = positions.shape[0]
    pos = positions.astype(jnp.int32)
    bidx = jnp.arange(b, dtype=jnp.int32)[:, None]
    col = jnp.clip(pos // page, 0, page_table.shape[1] - 1)
    phys = page_table[bidx, col]
    if kv_len is not None:
        phys = jnp.where(pos < kv_len.reshape(b, 1), phys, _TRASH_PAGE)
    return phys, pos % page


# ---------------------------------------------------------------------------
# protocol


class AttentionBackend:
    """One physical realization of the attention operation.

    Subclasses set ``name``/``mode`` and implement the five protocol
    methods (``cache_spec``, ``page_spec``, ``prefill``, ``decode``,
    ``paged_decode``) plus the ``write_cache`` splice used by the
    contiguous prefill path.  All array arguments follow the
    ``core/attention`` conventions: q (B, H, Sq, D), k/v (B, H_kv, S, D),
    GQA never materializes repeated KV.
    """

    name: str = "?"
    mode: str = "?"  # core/attention AttentionSpec operator mode

    # -- operator spec --------------------------------------------------
    def spec(self, cfg) -> AttentionSpec:
        return AttentionSpec(
            mode=self.mode,
            k_top=cfg.k_top,
            group_size=cfg.group_size,
            stage1_k=cfg.stage1_k,
            use_kernel=cfg.use_kernel,
        )

    # -- layouts --------------------------------------------------------
    def cache_spec(self, cfg, batch: int, cache_len: int, dtype) -> dict:
        """{leaf: (ShapeDtypeStruct, logical axes)} for one layer's
        contiguous self-attention cache."""
        raise NotImplementedError

    def page_spec(self, cfg, n_pages: int, page_size: int, max_batch: int,
                  dtype) -> dict:
        """{leaf: (ShapeDtypeStruct, logical axes)} for one layer's PAGED
        pool (serving/kv_cache.py page-table geometry)."""
        raise NotImplementedError

    def cache_bytes_per_token(self, cfg, dtype) -> float:
        """KV bytes appended per token per layer (capacity accounting)."""
        raise NotImplementedError

    # -- attention ------------------------------------------------------
    def prefill(self, q, k, v, cfg, *, causal=True, positions=None,
                window=None):
        """Attention over freshly computed K/V (train / whole-prompt
        prefill / cross-attention).  Returns (B, H, Sq, Dv)."""
        return attention(q, k, v, self.spec(cfg), causal=causal,
                         q_positions=positions, window=window)

    def decode(self, q, cache, k, v, cache_index, kv_len, positions, cfg, *,
               kv_positions=None, window=None):
        """Write k/v at ``cache_index`` then attend against the contiguous
        cache.  Returns (out, new_cache)."""
        raise NotImplementedError

    def paged_decode(self, q, cache, k, v, positions, page_table, kv_len,
                     cfg, *, base=None):
        """Splice k/v into the paged pools at their logical positions and
        attend through the page table (decode rows AND chunked-prefill
        rows — the single serving path).  Returns (out, new_pools).

        ``base`` (B,) is each slot's prefix-sharing offset: positions
        below it were prefilled by ANOTHER slot into shared pages, so
        per-slot running statistics (camformer's ``k_scale``) must count
        only positions >= base.  None means no sharing (all zeros).

        Fused-step entry (module docstring): called for every batch row
        of every tick inside one jit.  Rows with ``kv_len == 0`` are
        INERT — implementations must route their writes to the trash
        page (``_page_phys_rows`` does this when given kv_len) and leave
        their per-slot statistics untouched; their attention output is
        unspecified and never read.
        """
        raise NotImplementedError

    # -- analytic decode-step I/O accounting ----------------------------
    def paged_io_stats(self, cfg, dtype, *, kv_len: int, page_size: int,
                       n_table_pages: int) -> dict:
        """Analytic per-layer, per-slot decode-step I/O in bytes.

        ``fused_read_bytes``/``gather_read_bytes``: KV pool bytes READ
        per decode token by each ``paged_impl`` realization (fused walks
        only the slot's LIVE pages; gather dereferences the full
        ``n_table_pages`` table extent).  ``gather_scratch_bytes``: the
        peak logical-order scratch the gather impl materializes per slot
        (the fused kernels stream page tiles — zero scratch).  Benchmarks
        multiply by ``n_layers`` / batch for the system-level numbers.

        ``prefill_fused_read_bytes``/``prefill_gather_read_bytes``: the
        same accounting for one Sq > 1 CHUNK attend (chunked prefill /
        speculative verify) under each ``prefill_impl`` realization —
        the chunk reads the pools once regardless of chunk length, so
        bytes per prefill TOKEN divide by the chunk size.
        """
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        item = jnp.dtype(dtype).itemsize
        row = 2 * hkv * d * item  # one K row + one V row, all kv heads
        live_rows = -(-max(kv_len, 1) // page_size) * page_size
        table_rows = n_table_pages * page_size
        return {
            "fused_read_bytes": live_rows * row,
            "gather_read_bytes": table_rows * row,
            "gather_scratch_bytes": table_rows * row,
            "prefill_fused_read_bytes": live_rows * row,
            "prefill_gather_read_bytes": table_rows * row,
        }

    # -- contiguous-cache write (shared ring-buffer clamp) --------------
    def write_cache(self, cache, k, v, index, cfg):
        """Insert new K/V at `index` (traced) along the cache seq axis.

        If the update is longer than the cache (window ring-buffer
        prefill), only the trailing cache-length slice is stored at 0.
        """
        if cache is None:
            return None
        cache_len = cache["v"].shape[2]
        if k.shape[2] > cache_len:
            k, v = k[:, :, -cache_len:], v[:, :, -cache_len:]
            index = jnp.int32(0)
        return self._write(cache, k, v, index, cfg)

    def _write(self, cache, k, v, index, cfg):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# dense


class DenseBackend(AttentionBackend):
    """Standard softmax attention over full-precision K/V (the oracle)."""

    name = "dense"
    mode = "dense"

    def cache_spec(self, cfg, batch, cache_len, dtype):
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        return {
            "k": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
                  ("batch", "kv_heads", "kv_seq", "head_dim")),
            "v": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
                  ("batch", "kv_heads", "kv_seq", "head_dim")),
        }

    def page_spec(self, cfg, n_pages, page_size, max_batch, dtype):
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        return {
            "k_pages": (jax.ShapeDtypeStruct(
                (n_pages, hkv, page_size, d), dtype),
                (None, "kv_heads", None, "head_dim")),
            "v_pages": (jax.ShapeDtypeStruct(
                (n_pages, hkv, page_size, d), dtype),
                (None, "kv_heads", None, "head_dim")),
        }

    def cache_bytes_per_token(self, cfg, dtype):
        d = cfg.head_dim
        return 2 * cfg.n_kv_heads * d * jnp.dtype(dtype).itemsize

    def _write(self, cache, k, v, index, cfg):
        return {"k": _seq_insert(cache["k"], k.astype(cache["k"].dtype), index),
                "v": _seq_insert(cache["v"], v.astype(cache["v"].dtype), index)}

    def decode(self, q, cache, k, v, cache_index, kv_len, positions, cfg, *,
               kv_positions=None, window=None):
        new_cache = self.write_cache(cache, k, v, cache_index, cfg)
        ck, cv = new_cache["k"], new_cache["v"]
        kv_pos = (jnp.arange(ck.shape[2], dtype=jnp.int32)[None]
                  if kv_positions is None else kv_positions)
        kv_valid = kv_pos < kv_len.reshape(-1, 1)
        out = attention(
            q, ck, cv, self.spec(cfg), causal=True,
            q_positions=positions, kv_positions=kv_pos,
            kv_valid=kv_valid, window=window or cfg.window)
        return out, new_cache

    def _paged_write(self, cache, k, v, positions, page_table, kv_len=None):
        page = cache["k_pages"].shape[2]
        phys, row = _page_phys_rows(page_table, positions, page, kv_len)
        new_k = cache["k_pages"].at[phys, :, row].set(
            k.astype(cache["k_pages"].dtype).transpose(0, 2, 1, 3))
        new_v = cache["v_pages"].at[phys, :, row].set(
            v.astype(cache["v_pages"].dtype).transpose(0, 2, 1, 3))
        return {"k_pages": new_k, "v_pages": new_v}

    def paged_decode(self, q, cache, k, v, positions, page_table, kv_len,
                     cfg, *, base=None):
        # dense pages carry no per-slot running statistics: `base` only
        # affects which positions are freshly written, which the page
        # table already encodes
        new_cache = self._paged_write(cache, k, v, positions, page_table,
                                      kv_len)
        out = self._paged_attend(q, new_cache, positions, page_table,
                                 kv_len, cfg)
        return out, new_cache

    def _paged_attend(self, q, cache, positions, page_table, kv_len, cfg):
        sq = q.shape[2]
        impl = cfg.paged_impl if sq == 1 else cfg.prefill_paged_impl
        if impl == "fused":
            # Fused paged flash kernel (kernels/paged_flash_decode.py):
            # page-table walk with an online softmax — bytes
            # proportional to live pages, no logical-order gather.
            # Sq > 1 chunk rows (chunked prefill / speculative verify)
            # run the same skeleton with per-row causal anchors keyed
            # on the chunk's first position (the slot's offsets).
            from repro.kernels import ops as kops

            if sq == 1:
                return kops.paged_flash_decode(
                    q, cache["k_pages"], cache["v_pages"], page_table,
                    kv_len.reshape(-1), positions[:, 0], window=cfg.window)
            return kops.paged_flash_prefill(
                q, cache["k_pages"], cache["v_pages"], page_table,
                kv_len.reshape(-1), positions[:, 0], window=cfg.window)
        from repro.kernels.ref import paged_gather_ref

        # Reference impl: gather the slot's pages into logical order
        # and run the standard masked attend — logical position p is
        # row p of the gather, so the contiguous-cache masking applies
        # verbatim.
        ck = paged_gather_ref(cache["k_pages"], page_table)
        cv = paged_gather_ref(cache["v_pages"], page_table)
        kv_pos = jnp.arange(ck.shape[2], dtype=jnp.int32)[None]
        kv_valid = kv_pos < kv_len.reshape(-1, 1)
        return attention(
            q, ck, cv, self.spec(cfg), causal=True,
            q_positions=positions, kv_positions=kv_pos,
            kv_valid=kv_valid, window=cfg.window)


class BinaryBackend(DenseBackend):
    """HAD-binarized scoring with a FULL softmax (no top-k sparsity).

    Contiguous storage is identical to dense (keys binarize at attend
    time); only the scoring arithmetic changes — the single-stage upper
    bound of the Tables III/IV ablation ladder.

    The PAGED pools additionally carry camformer's running per-slot
    ``k_scale`` (HAD softmax-temperature bookkeeping, maintained at
    page-write time over valid tokens only), which makes the paged path
    genuinely binarized: before, ``paged_decode`` inherited the dense
    gather + full-precision softmax wholesale, so the "binary" serving
    lane measured gather cost rather than sign-match scoring — and a
    streaming kernel could not reproduce the old temperature anyway
    (a mean over ALL gathered rows, trash-page garbage included).
    """

    name = "binary"
    mode = "binary"

    def page_spec(self, cfg, n_pages, page_size, max_batch, dtype):
        spec = super().page_spec(cfg, n_pages, page_size, max_batch, dtype)
        spec["k_scale"] = (
            jax.ShapeDtypeStruct((max_batch, cfg.n_kv_heads), jnp.float32),
            ("batch", "kv_heads"))
        if cfg.spec_k > 0:
            # speculative verify scratch: the tick's per-position key
            # means, so the accept-prefix rollback can rebuild the
            # running k_scale at the accepted length exactly
            spec["k_means"] = (
                jax.ShapeDtypeStruct(
                    (max_batch, cfg.n_kv_heads, cfg.spec_k + 1),
                    jnp.float32),
                ("batch", "kv_heads", None))
        return spec

    def _paged_write(self, cache, k, v, positions, page_table, kv_len=None,
                     base=None):
        pages = super()._paged_write(cache, k, v, positions, page_table,
                                     kv_len)
        b = k.shape[0]
        pos = positions.astype(jnp.int32)
        kvl = (jnp.full((b,), pos.shape[1], jnp.int32) if kv_len is None
               else kv_len.reshape(b).astype(jnp.int32))
        pages["k_scale"] = _running_k_scale(
            cache["k_scale"], k, pos, kvl, base)
        if "k_means" in cache:
            pages["k_means"] = cache["k_means"]
        return pages

    def paged_decode(self, q, cache, k, v, positions, page_table, kv_len,
                     cfg, *, base=None):
        new_cache = self._paged_write(cache, k, v, positions, page_table,
                                      kv_len, base=base)
        k_scale = new_cache["k_scale"]
        if (q.shape[2] > 1 and cfg.spec_verify and "k_means" in new_cache
                and new_cache["k_means"].shape[-1] == q.shape[2]):
            # speculative verify chunk: sequential-semantics per-query
            # scales, and stash the chunk means for exact rollback
            k_scale, means = _chunk_scale_seq(
                cache["k_scale"], k, positions.astype(jnp.int32),
                kv_len.reshape(k.shape[0]).astype(jnp.int32), base)
            new_cache["k_means"] = means
        # decode rows follow paged_impl; Sq > 1 chunk rows (prefill /
        # verify — the per-query scales above fold into the kernel's
        # temperature operand) follow the effective prefill impl
        impl = cfg.paged_impl if q.shape[2] == 1 else cfg.prefill_paged_impl
        out = binary_paged_attention(
            q, new_cache["k_pages"], new_cache["v_pages"],
            k_scale, page_table, kv_len, positions,
            self.spec(cfg), window=cfg.window, impl=impl)
        return out, new_cache


# ---------------------------------------------------------------------------
# camformer


class CamformerBackend(AttentionBackend):
    """The paper's BA-CAM realization: bit-packed binary Key SRAM,
    two-stage top-k CAM search, softmax over the k survivors, sparse
    top-k V gather; fused Pallas kernels on the decode hot paths."""

    name = "camformer"
    mode = "camformer"

    def cache_spec(self, cfg, batch, cache_len, dtype):
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        return {
            "k_packed": (jax.ShapeDtypeStruct(
                (batch, hkv, cache_len, d // 32), jnp.uint32),
                ("batch", "kv_heads", "kv_seq", None)),
            "v": (jax.ShapeDtypeStruct((batch, hkv, cache_len, d), dtype),
                  ("batch", "kv_heads", "kv_seq", "head_dim")),
            "k_scale": (jax.ShapeDtypeStruct((batch, hkv), jnp.float32),
                        ("batch", "kv_heads")),
        }

    def page_spec(self, cfg, n_pages, page_size, max_batch, dtype):
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        if page_size % cfg.group_size != 0:
            raise ValueError(
                f"page_size={page_size} must tile by "
                f"group_size={cfg.group_size}")
        spec = {
            "kp_pages": (jax.ShapeDtypeStruct(
                (n_pages, hkv, page_size, d // 32), jnp.uint32),
                (None, "kv_heads", None, None)),
            "v_pages": (jax.ShapeDtypeStruct(
                (n_pages, hkv, page_size, d), dtype),
                (None, "kv_heads", None, "head_dim")),
            "k_scale": (jax.ShapeDtypeStruct((max_batch, hkv), jnp.float32),
                        ("batch", "kv_heads")),
        }
        if cfg.spec_k > 0:
            # speculative verify scratch (see BinaryBackend.page_spec) —
            # doubly necessary here: the packed pool stores signs only,
            # so chunk key magnitudes are unrecoverable after the write
            spec["k_means"] = (
                jax.ShapeDtypeStruct(
                    (max_batch, hkv, cfg.spec_k + 1), jnp.float32),
                ("batch", "kv_heads", None))
        return spec

    def cache_bytes_per_token(self, cfg, dtype):
        d = cfg.head_dim
        return cfg.n_kv_heads * (d // 8 + d * jnp.dtype(dtype).itemsize)

    def _write(self, cache, k, v, index, cfg):
        kp = bacam.pack_bits(sign_pm1(k))
        new_kp = _seq_insert(cache["k_packed"], kp, index)
        new_v = _seq_insert(cache["v"], v.astype(cache["v"].dtype), index)
        # running per-head key scale (softmax temperature bookkeeping)
        step = jnp.float32(k.shape[2])
        new_mean = jnp.mean(jnp.abs(k.astype(jnp.float32)), axis=(2, 3))
        idx_f = jnp.reshape(index.astype(jnp.float32), (-1, 1))
        total = idx_f + step
        k_scale = (cache["k_scale"] * idx_f + new_mean * step) / total
        return {"k_packed": new_kp, "v": new_v, "k_scale": k_scale}

    def decode(self, q, cache, k, v, cache_index, kv_len, positions, cfg, *,
               kv_positions=None, window=None):
        new_cache = self.write_cache(cache, k, v, cache_index, cfg)
        # distributed CAM search targets the batch=1 long-context regime
        # where the cache sequence takes every mesh axis; batched decode
        # keeps batch-sharded local search instead
        if cfg.distributed_topk and kv_positions is None and q.shape[0] == 1:
            out = self._distributed_attend(
                q, new_cache, kv_len, positions, cfg)
        else:
            out = self._cache_attend(
                q, new_cache, kv_len, positions, cfg,
                kv_positions=kv_positions)
        return out, new_cache

    def paged_decode(self, q, cache, k, v, positions, page_table, kv_len,
                     cfg, *, base=None):
        new_cache = self._paged_write(
            cache, k, v, positions, page_table, kv_len, cfg, base=base)
        k_scale = new_cache["k_scale"]
        if (q.shape[2] > 1 and cfg.spec_verify and "k_means" in new_cache
                and new_cache["k_means"].shape[-1] == q.shape[2]):
            # speculative verify chunk (see BinaryBackend.paged_decode)
            k_scale, means = _chunk_scale_seq(
                cache["k_scale"], k, positions.astype(jnp.int32),
                kv_len.reshape(k.shape[0]).astype(jnp.int32), base)
            new_cache["k_means"] = means
        out = camformer_paged_attention(
            q, new_cache["kp_pages"], new_cache["v_pages"],
            k_scale, page_table, kv_len, positions,
            self.spec(cfg), window=cfg.window, impl=cfg.paged_impl)
        return out, new_cache

    def paged_io_stats(self, cfg, dtype, *, kv_len, page_size,
                       n_table_pages):
        hkv, d = cfg.n_kv_heads, cfg.head_dim
        item = jnp.dtype(dtype).itemsize
        kp_row = hkv * (d // 8)  # bit-packed keys: 1 bit/element
        live_rows = -(-max(kv_len, 1) // page_size) * page_size
        table_rows = n_table_pages * page_size
        # V is never gathered: only the k_top survivors are read, per
        # GQA query row (worst case all-unique selections).
        g = cfg.n_heads // hkv
        v_sel = hkv * g * min(cfg.k_top, kv_len or 1) * d * item
        return {
            "fused_read_bytes": live_rows * kp_row + v_sel,
            "gather_read_bytes": table_rows * kp_row + v_sel,
            "gather_scratch_bytes": table_rows * kp_row,
            # no fused Sq>1 CAM kernel yet (ROADMAP stretch): chunk
            # attends gather the packed pool under either prefill_impl
            "prefill_fused_read_bytes": table_rows * kp_row + v_sel,
            "prefill_gather_read_bytes": table_rows * kp_row + v_sel,
        }

    # -- internals ------------------------------------------------------
    def _paged_write(self, cache, k, v, positions, page_table, kv_len, cfg,
                     base=None):
        """Splice new K/V into the paged pools at their logical positions.

        k, v: (B, H_kv, S, D); positions: (B, S) logical token positions;
        kv_len: (B,) — valid tokens per slot INCLUDING this write
        (prefill: the true prompt length; decode: pos + 1).  Tokens at
        positions >= kv_len are right-padding: their page-table entries
        resolve to the trash page and they are excluded from the k_scale
        running mean.

        base: (B,) prefix-sharing offset.  The slot's k_scale running
        mean counts only the positions THIS slot computed (>= base) —
        tokens below base live in shared pages written by another slot,
        whose k contribution this slot never sees.  The suffix mean is
        the sharing approximation for the softmax temperature; it keeps
        k_scale strictly per-slot state (fork siblings stay independent).
        """
        page = cache["kp_pages"].shape[2]
        b = k.shape[0]
        pos = positions.astype(jnp.int32)
        kv_len = kv_len.reshape(b).astype(jnp.int32)
        phys, row = _page_phys_rows(page_table, pos, page, kv_len)

        kp = bacam.pack_bits(sign_pm1(k))  # (B, H_kv, S, W)
        new_kp = cache["kp_pages"].at[phys, :, row].set(
            kp.transpose(0, 2, 1, 3))
        new_v = cache["v_pages"].at[phys, :, row].set(
            v.astype(cache["v_pages"].dtype).transpose(0, 2, 1, 3))

        ks = _running_k_scale(cache["k_scale"], k, pos, kv_len, base)
        pages = {"kp_pages": new_kp, "v_pages": new_v, "k_scale": ks}
        if "k_means" in cache:
            pages["k_means"] = cache["k_means"]
        return pages

    def _cache_attend(self, q, cache, kv_len, positions, cfg,
                      kv_positions=None):
        """Decode/serve attention against the packed binary cache."""
        spec = self.spec(cfg)
        b, h, sq, d = q.shape
        hkv = cfg.n_kv_heads
        g = h // hkv
        skv = cache["v"].shape[2]
        qb = sign_pm1(q.astype(jnp.float32))
        q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)  # (B,H,Sq)

        qp = bacam.pack_bits(qb).reshape(b * hkv, g * sq, d // 32)
        kp = cache["k_packed"].reshape(b * hkv, skv, d // 32)
        if spec.use_kernel and kv_positions is not None:
            # the fused kernel masks from slot order; ring caches with
            # rotated positions take the jnp path instead
            spec = spec.replace(use_kernel=False)
        if spec.use_kernel:
            from repro.kernels import ops as kops

            pos = jnp.broadcast_to(
                positions[:, None, :], (b, hkv, g * sq)).reshape(
                b * hkv, g * sq)
            kvl = jnp.broadcast_to(
                kv_len.reshape(b, 1), (b, hkv)).reshape(b * hkv)
            cand_v, cand_i = kops.bacam_attention_scores_topk_packed(
                qp, kp, pos, kvl, d=d,
                group=spec.group_size, stage1_k=spec.stage1_k,
                causal=True, window=cfg.window)
            top_v, sel = jax.lax.top_k(
                cand_v, min(spec.k_top, cand_v.shape[-1]))
            top_i = jnp.take_along_axis(cand_i, sel, axis=-1)
            top_v = top_v.reshape(b, hkv, g, sq, -1)
            top_i = top_i.reshape(b, hkv, g, sq, -1)
        else:
            scores = bacam.hamming_scores_packed(
                qp.reshape(b, hkv, g * sq, d // 32),
                kp.reshape(b, hkv, skv, d // 32),
                d,
            )  # (B,Hkv,G*Sq,Skv)
            if kv_positions is None:
                kpos = jnp.arange(skv, dtype=jnp.int32)[None, None, None]
            else:  # ring cache: slots hold true (rotated) positions
                kpos = kv_positions[:, None, None, :]
            qpos = jnp.broadcast_to(positions[:, None, :], (b, hkv, sq))
            qpos = jnp.broadcast_to(
                qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
                b, hkv, g * sq)[..., None]
            ok = kpos < kv_len.reshape(b, 1, 1, 1)
            ok = ok & (kpos <= qpos)
            if cfg.window is not None:
                ok = ok & (kpos > qpos - cfg.window)
            masked = jnp.where(ok, scores.astype(jnp.float32), NEG_INF)
            top_v, top_i = two_stage_topk(
                masked, k=spec.k_top, group_size=spec.group_size,
                stage1_k=spec.stage1_k)
            top_v = top_v.reshape(b, hkv, g, sq, -1)
            top_i = top_i.reshape(b, hkv, g, sq, -1)

        scale = 1.0 / (d**0.5)
        temp = (q_scale.reshape(b, hkv, g, sq)[..., None]
                * cache["k_scale"][:, :, None, None, None])
        w, _ = topk_softmax_weights(top_v, temp, scale)
        v_exp = cache["v"][:, :, None, None]  # (B,Hkv,1,1,Skv,Dv)
        v_sel = jnp.take_along_axis(v_exp, top_i[..., None], axis=-2)
        out = jnp.einsum(
            "bhgqk,bhgqkd->bhgqd", w.astype(cache["v"].dtype), v_sel)
        return out.reshape(b, h, sq, d).astype(q.dtype)

    def _distributed_attend(self, q, cache, kv_len, positions, cfg):
        """Distributed CAM search (paper Sec. IV-C at cluster scale).

        The packed-binary cache is sequence-sharded across the mesh; each
        shard runs the BA-CAM scoring + two-stage top-k LOCALLY, shards
        exchange only their k candidates (k*(8 B) per query per shard — vs
        gathering the full N-score matchline vector), the global
        top-k/softmax is computed redundantly everywhere, and
        contextualization is a masked partial sum over local V rows
        finished by one psum.
        """
        env = compat.get_abstract_mesh()
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in getattr(env, "shape", {}) and env.shape[a] > 1)
        if not axes:
            return self._cache_attend(q, cache, kv_len, positions, cfg)
        from jax.sharding import PartitionSpec as P

        spec = self.spec(cfg)
        b, h, sq, d = q.shape
        hkv = cfg.n_kv_heads
        g = h // hkv
        skv = cache["v"].shape[2]
        n_shards = math.prod(env.shape[a] for a in axes)
        s_local = skv // n_shards
        qb = sign_pm1(q.astype(jnp.float32))
        q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)
        qp = bacam.pack_bits(qb).reshape(b, hkv, g * sq, d // 32)

        k_top = spec.k_top

        def local_fn(qp_l, kp_l, v_l, kscale_l, qscale_l, pos_l, kvlen_l):
            # shard offset along the cache sequence
            idx = 0
            for a in axes:
                idx = idx * env.shape[a] + jax.lax.axis_index(a)
            offset = idx * s_local
            scores = bacam.hamming_scores_packed(
                qp_l, kp_l, d).astype(jnp.float32)
            kpos = offset + jnp.arange(
                s_local, dtype=jnp.int32)[None, None, None]
            qpos = jnp.broadcast_to(pos_l[:, None, :], (b, hkv, sq))
            qpos = jnp.broadcast_to(
                qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
                b, hkv, g * sq)[..., None]
            ok = (kpos < kvlen_l.reshape(b, 1, 1, 1)) & (kpos <= qpos)
            if cfg.window is not None:
                ok = ok & (kpos > qpos - cfg.window)
            masked = jnp.where(ok, scores, NEG_INF)
            lv, li = two_stage_topk(
                masked, k=k_top, group_size=spec.group_size,
                stage1_k=spec.stage1_k)  # local top-k
            li = li + offset  # globalize indices
            # exchange candidates only: (B,Hkv,R,k) per shard
            cv = jax.lax.all_gather(lv, axes, axis=-1, tiled=True)
            ci = jax.lax.all_gather(li, axes, axis=-1, tiled=True)
            top_v, sel = jax.lax.top_k(cv, k_top)  # identical on every shard
            top_i = jnp.take_along_axis(ci, sel, axis=-1)
            scale = 1.0 / (d**0.5)
            temp = (qscale_l.reshape(b, hkv, g * sq)[..., None]
                    * kscale_l[:, :, None, None])
            w, valid = topk_softmax_weights(top_v, temp, scale)
            # partial contextualization over local V rows
            mine = (top_i >= offset) & (top_i < offset + s_local) & valid
            loc = jnp.clip(top_i - offset, 0, s_local - 1)
            v_exp = v_l[:, :, None]  # (B,Hkv,1,S_local,D)
            v_sel = jnp.take_along_axis(v_exp, loc[..., None], axis=-2)
            contrib = jnp.einsum(
                "bhrk,bhrkd->bhrd",
                jnp.where(mine, w, 0.0).astype(jnp.float32),
                v_sel.astype(jnp.float32))
            return jax.lax.psum(contrib, axes)

        seq_spec = P(None, None, axes, None)
        out = compat.shard_map(
            local_fn,
            mesh=env,
            in_specs=(P(), seq_spec,
                      P(None, None, axes, None), P(), P(), P(), P()),
            out_specs=P(),
        )(qp, cache["k_packed"], cache["v"], cache["k_scale"], q_scale,
          positions, kv_len)
        out = out.reshape(b, hkv, g, sq, d).reshape(b, h, sq, d)
        return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# hybrid: flash-scored prefill + CAM decode


class HybridBackend(CamformerBackend):
    """Flash-prefill hybrid: dense flash-scored fused prefill chunks +
    CAM paged decode — the analog/digital split of charge-based hybrid
    attention accelerators layered on X-Former-style mixed tiling.

    The paged pools carry BOTH key representations: a dense ``k_pages``
    pool for the Sq > 1 chunk path — chunked prefill, the TTFT-critical
    hot path, runs the fused paged flash kernel with an EXACT softmax —
    and the bit-packed ``kp_pages`` + running ``k_scale`` for the CAM
    decode path (two-stage top-k search per generated token).  Every
    page write updates both, so either attend is always current.

    Speculative VERIFY chunks (``cfg.spec_verify``) deliberately take
    the CAM path with sequential per-query scales (``_chunk_scale_seq``
    + the ``k_means`` stash): speculation's exactness contract
    (serving/speculate.py) is that verify logits reproduce what the
    TARGET's sequential decode would emit — and this backend's decode
    is CAM, so flash-scoring the verify chunk would break token-level
    acceptance.  Only non-verify prefill chunks flash-score.

    Cost: the dense K pool adds ``H_kv * D * itemsize`` bytes/token over
    camformer (values dominate either way); in exchange prefill keeps
    full softmax fidelity AND live-page-proportional reads.
    """

    name = "hybrid"
    mode = "camformer"

    def page_spec(self, cfg, n_pages, page_size, max_batch, dtype):
        spec = super().page_spec(cfg, n_pages, page_size, max_batch, dtype)
        spec["k_pages"] = (jax.ShapeDtypeStruct(
            (n_pages, cfg.n_kv_heads, page_size, cfg.head_dim), dtype),
            (None, "kv_heads", None, "head_dim"))
        return spec

    def cache_bytes_per_token(self, cfg, dtype):
        d = cfg.head_dim
        item = jnp.dtype(dtype).itemsize
        # packed keys + dense keys (flash prefill) + dense values
        return cfg.n_kv_heads * (d // 8 + 2 * d * item)

    def _paged_write(self, cache, k, v, positions, page_table, kv_len, cfg,
                     base=None):
        pages = super()._paged_write(cache, k, v, positions, page_table,
                                     kv_len, cfg, base=base)
        page = cache["k_pages"].shape[2]
        b = k.shape[0]
        phys, row = _page_phys_rows(
            page_table, positions.astype(jnp.int32), page,
            kv_len.reshape(b).astype(jnp.int32))
        pages["k_pages"] = cache["k_pages"].at[phys, :, row].set(
            k.astype(cache["k_pages"].dtype).transpose(0, 2, 1, 3))
        return pages

    def prefill(self, q, k, v, cfg, *, causal=True, positions=None,
                window=None):
        # whole-prompt prefill / training attend: flash-scored (exact
        # softmax), matching the paged chunk path below
        return get_backend("dense").prefill(
            q, k, v, cfg, causal=causal, positions=positions, window=window)

    def paged_decode(self, q, cache, k, v, positions, page_table, kv_len,
                     cfg, *, base=None):
        if q.shape[2] > 1 and not cfg.spec_verify:
            # flash-scored prefill chunk over the dense key pool; the
            # packed pool and running k_scale were updated by the same
            # write, so the CAM decode that follows reads current state
            new_cache = self._paged_write(
                cache, k, v, positions, page_table, kv_len, cfg, base=base)
            out = get_backend("dense")._paged_attend(
                q, new_cache, positions, page_table, kv_len, cfg)
            return out, new_cache
        # decode rows and speculative verify chunks: the CAM search
        # path (verify must reproduce the sequential CAM decode)
        return super().paged_decode(q, cache, k, v, positions, page_table,
                                    kv_len, cfg, base=base)

    def paged_io_stats(self, cfg, dtype, *, kv_len, page_size,
                       n_table_pages):
        stats = super().paged_io_stats(
            cfg, dtype, kv_len=kv_len, page_size=page_size,
            n_table_pages=n_table_pages)
        # decode columns stay CAM; prefill chunks read the DENSE pools
        item = jnp.dtype(dtype).itemsize
        row = 2 * cfg.n_kv_heads * cfg.head_dim * item
        live_rows = -(-max(kv_len, 1) // page_size) * page_size
        stats["prefill_fused_read_bytes"] = live_rows * row
        stats["prefill_gather_read_bytes"] = n_table_pages * page_size * row
        return stats


register_backend(DenseBackend())
register_backend(BinaryBackend())
register_backend(CamformerBackend())
register_backend(HybridBackend())
