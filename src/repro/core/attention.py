"""CAMformer attention: Eq. 1 of the paper as a composable JAX module.

    CAMformer-Attn(Q, K, V) = SoftMax(Top-32(QK^T)) . V

with QK^T computed on binarized operands by the BA-CAM device model (or its
Pallas kernel) and Top-32 realized as the two-stage hierarchical top-k.

Three modes, forming the ablation ladder of Tables III/IV:

  * ``dense``     — standard softmax attention (the oracle / teacher).
  * ``binary``    — HAD-binarized Q/K, *full* softmax over all N binary
                    scores (single-stage upper bound, no sparsity).
  * ``camformer`` — binary scores -> two-stage top-k -> softmax over the k
                    survivors -> sparse V contextualization (the paper).

GQA is supported natively: q may have H = G * H_kv heads against H_kv
key/value heads; K/V are never materialized repeated.

Ordering note (faithfulness): the CAM selects on the *raw* binary score
(matchline voltage).  HAD's per-tensor scales therefore only enter as a
softmax temperature, never in the selection — we reduce the key scale per
head (not per row) so selection ordering matches the hardware exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import bacam
from repro.core.binarize import binarize_qk
from repro.core.topk import NEG_INF, two_stage_topk

__all__ = [
    "AttentionSpec", "attention", "binary_paged_attention",
    "camformer_paged_attention", "dense_reference", "make_mask",
    "topk_softmax_weights",
]


def topk_softmax_weights(top_v, temp, scale):
    """Softmax over top-k survivors (the hardware's LUT stage).

    top_v: (..., k) raw binary scores with NEG_INF at masked entries;
    temp: HAD temperature, broadcastable to top_v; scale: 1/sqrt(d).
    Returns (weights, valid) — weights are exactly 0 at invalid entries
    (callers must also zero any values gathered for them before a
    fused multiply-add, to avoid reading garbage at weight 0).
    """
    valid = top_v > NEG_INF / 2
    logits = jnp.where(valid, top_v * temp * scale, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.where(valid, w, 0.0), valid


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    """Configuration of the attention operator (first-class feature)."""

    mode: str = "dense"  # dense | binary | camformer
    k_top: int = 32
    group_size: int = 16  # CAM_H
    stage1_k: int = 2
    # Device-fidelity knobs (benchmarks only; None/0.0 == exact integer path)
    adc_bits: Optional[int] = None
    noise_sigma: float = 0.0
    cam_w: int = bacam.CAM_W
    # Straight-through estimator for training binarized models (HAD)
    trainable_binarize: bool = False
    # Route binary scoring through the Pallas BA-CAM kernel
    use_kernel: bool = False

    def replace(self, **kw) -> "AttentionSpec":
        return dataclasses.replace(self, **kw)


def make_mask(
    sq: int,
    skv: int,
    *,
    causal: bool = True,
    q_positions: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    kv_valid: jax.Array | None = None,
    window: int | None = None,
):
    """Build a boolean validity mask, broadcastable to (B, 1, Sq, Skv).

    Built from iota comparisons (never a materialized (S,S) constant in HBM —
    XLA fuses these).  ``q_positions``/``kv_positions`` may be traced (decode
    against a rotating cache); defaults are arange.
    """
    if q_positions is None:
        q_positions = jnp.arange(sq, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_positions = jnp.arange(skv, dtype=jnp.int32)[None, :]
    qp = q_positions[:, :, None]  # (B?, Sq, 1)
    kp = kv_positions[:, None, :]  # (B?, 1, Skv)
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    if kv_valid is not None:
        mask &= kv_valid[:, None, :]
    return mask[:, None]  # (B?, 1, Sq, Skv) — head axis broadcasts


def _split_gqa(q: jax.Array, h_kv: int) -> jax.Array:
    """(B, H, Sq, D) -> (B, H_kv, G, Sq, D) without copying KV."""
    b, h, sq, d = q.shape
    if h % h_kv != 0:
        raise ValueError(f"H={h} not divisible by H_kv={h_kv}")
    return q.reshape(b, h_kv, h // h_kv, sq, d)


def _binary_scores(qg, k, spec: AttentionSpec, rng):
    """Binary scores (B, Hkv, G, Sq, Skv) + softmax temperature scale."""
    qb, kb, q_scale, k_scale = binarize_qk(
        qg, k, trainable=spec.trainable_binarize, with_scales=True
    )
    if spec.adc_bits is None and spec.noise_sigma == 0.0:
        if spec.use_kernel:
            from repro.kernels import ops as kops  # local import: no cycle

            b, hkv, g, sq, d_ = qb.shape
            skv = kb.shape[-2]
            s3 = kops.bacam_scores(
                qb.reshape(b * hkv, g * sq, d_), kb.reshape(b * hkv, skv, d_)
            )
            scores = s3.reshape(b, hkv, g, sq, skv)
        else:
            scores = bacam.bacam_scores(qb[...], kb[:, :, None], exact=True)
    else:
        kb = kb[:, :, None]  # broadcast against the GQA group axis
        scores = bacam.bacam_scores(
            qb,
            kb,
            cam_w=spec.cam_w,
            adc_bits=spec.adc_bits,
            noise_sigma=spec.noise_sigma,
            rng=rng,
            exact=False,
        )
    # HAD temperature: per-(query-row) q scale (order-preserving per row) and
    # per-head k scale (selection on raw scores == hardware).
    k_scale_head = jnp.mean(k_scale, axis=-2, keepdims=True)  # (B,Hkv,1,1)
    temp = q_scale * k_scale_head[..., None, :, :]  # (B,Hkv,G,Sq,1)
    return scores.astype(jnp.float32), temp


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    spec: AttentionSpec = AttentionSpec(),
    *,
    causal: bool = True,
    q_positions: jax.Array | None = None,
    kv_positions: jax.Array | None = None,
    kv_valid: jax.Array | None = None,
    window: int | None = None,
    scale: float | None = None,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Multi-head (GQA) attention with selectable CAMformer modes.

    Args:
      q: (B, H, Sq, D); k: (B, H_kv, Skv, D); v: (B, H_kv, Skv, Dv).
      causal/window/kv_valid/positions: masking controls (see make_mask).
      scale: score scale; default 1/sqrt(D).

    Returns: (B, H, Sq, Dv) in q's dtype.
    """
    b, h, sq, d = q.shape
    _, h_kv, skv, dv = v.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    qg = _split_gqa(q, h_kv)
    mask = make_mask(
        sq,
        skv,
        causal=causal,
        q_positions=q_positions,
        kv_positions=kv_positions,
        kv_valid=kv_valid,
        window=window,
    )  # (B?,1,Sq,Skv)
    mask5 = mask[:, :, None]  # (B?,1,1,Sq,Skv) — broadcast over (Hkv, G)

    if spec.mode == "dense":
        logits = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        logits = jnp.where(mask5, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)
        return out.reshape(b, h, sq, dv).astype(q.dtype)

    scores, temp = _binary_scores(qg, k, spec, rng)
    # XNOR-Net/HAD dequant: q.k ~ alpha_q*alpha_k*(qb.kb)  =>  logit = s*temp*scale
    logits = scores * temp * scale

    if spec.mode == "binary":
        logits = jnp.where(mask5, logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", w.astype(v.dtype), v)
        return out.reshape(b, h, sq, dv).astype(q.dtype)

    if spec.mode != "camformer":
        raise ValueError(f"unknown attention mode {spec.mode!r}")

    # --- CAMformer: select on RAW binary scores (hardware ordering) ---
    raw = jnp.where(mask5, scores, NEG_INF)
    top_v, top_i = two_stage_topk(
        raw, k=spec.k_top, group_size=spec.group_size, stage1_k=spec.stage1_k
    )  # (B,Hkv,G,Sq,K)
    valid = top_v > NEG_INF / 2
    # Temperature applies to the k survivors (softmax LUT stage).
    sel_logits = jnp.where(valid, top_v * temp * scale, NEG_INF)
    w = jax.nn.softmax(sel_logits, axis=-1)  # rows with <k valid stay correct
    # Sparse contextualization: gather only the k selected V rows.
    v_exp = v[:, :, None, None]  # (B,Hkv,1,1,Skv,Dv)
    idx = top_i[..., None]  # (B,Hkv,G,Sq,K,1)
    v_sel = jnp.take_along_axis(v_exp, idx, axis=-2)  # (B,Hkv,G,Sq,K,Dv)
    out = jnp.einsum("bhgqk,bhgqkd->bhgqd", w.astype(v.dtype), v_sel)
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def binary_paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    k_scale: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_positions: jax.Array,
    spec: AttentionSpec = AttentionSpec(mode="binary"),
    *,
    window: int | None = None,
    scale: float | None = None,
    impl: str = "fused",
) -> jax.Array:
    """Binary (HAD sign-match, FULL softmax) attention against the paged
    dense-storage K/V pools — the single-stage ablation point on the
    serving path.

    Scoring binarizes Q and the paged keys at attend time
    (``core/binarize.sign_pm1``); the softmax temperature is
    ``q_scale * k_scale`` with ``k_scale`` the slot's RUNNING per-head
    key scale maintained at page-write time (the camformer bookkeeping,
    shared via ``BinaryBackend.page_spec``) — a streamable per-slot
    statistic, unlike recomputing a mean over gathered rows, so the
    fused and gather realizations score identically and trash-page
    garbage never leaks into the temperature.

    ``impl="fused"`` runs the paged flash kernel
    (kernels/paged_flash_decode.py) with in-register K binarization —
    bytes proportional to live pages: decode rows (Sq == 1) through
    ``kops.paged_flash_decode``, chunk rows (Sq > 1: chunked prefill and
    speculative verify, whose per-query sequential scales arrive as a
    3-D ``k_scale`` and fold into the temperature) through
    ``kops.paged_flash_prefill`` with the per-row causal anchor.
    ``impl="gather"`` gathers the pages into logical order and runs the
    same masked full softmax in XLA (the pinned reference).

    Shapes as ``camformer_paged_attention`` but with dense
    ``k_pages`` (P, H_kv, page, D).  Returns (B, H, Sq, Dv).
    """
    from repro.core.binarize import sign_pm1

    b, h, sq, d = q.shape
    _, hkv, page, dv = v_pages.shape
    g = h // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    kv_len = kv_len.reshape(b).astype(jnp.int32)
    q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)  # (B,H,Sq)
    # k_scale: (B, Hkv) per-slot running scale, or (B, Hkv, Sq) per-QUERY
    # scales (speculative verify chunks: column j's scale covers keys up
    # to its own position — sequential-decode semantics).
    ks = k_scale.astype(jnp.float32)
    if ks.ndim == 2:
        ks = ks[:, :, None]
    ks = jnp.broadcast_to(ks[:, :, None, :], (b, hkv, g, sq))
    temp = q_scale.reshape(b, hkv, g * sq) * ks.reshape(b, hkv, g * sq)

    if impl == "fused":
        from repro.kernels import ops as kops  # local import: no cycle

        if sq == 1:
            return kops.paged_flash_decode(
                q, k_pages, v_pages, page_table, kv_len,
                q_positions.reshape(b).astype(jnp.int32),
                temp=temp, binary=True, window=window, scale=scale)
        # Chunk rows: positions are contiguous from the slot's offset,
        # so the kernel takes the first position + per-row anchors.
        return kops.paged_flash_prefill(
            q, k_pages, v_pages, page_table, kv_len,
            q_positions[:, 0].astype(jnp.int32),
            temp=temp, binary=True, window=window, scale=scale)

    # Gather reference: logical-order pages, same scoring arithmetic.
    from repro.kernels.ref import paged_gather_ref

    ck = paged_gather_ref(k_pages, page_table)  # (B, H_kv, S_log, D)
    cv = paged_gather_ref(v_pages, page_table)
    s_log = ck.shape[2]
    qb = sign_pm1(q.astype(jnp.float32)).reshape(b, hkv, g * sq, d)
    kb = sign_pm1(ck.astype(jnp.float32))
    scores = jnp.einsum("bhrd,bhkd->bhrk", qb, kb)
    kpos = jnp.arange(s_log, dtype=jnp.int32)[None, None, None]
    qpos = jnp.broadcast_to(q_positions[:, None, :], (b, hkv, sq))
    qpos = jnp.broadcast_to(qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
        b, hkv, g * sq)[..., None]
    ok = (kpos < kv_len.reshape(b, 1, 1, 1)) & (kpos <= qpos)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    logits = scores * temp[..., None] * scale
    logits = jnp.where(ok, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    w = jnp.where(ok, w, 0.0)  # inert rows: all-zero weights, zero out
    out = jnp.einsum("bhrk,bhkd->bhrd", w, cv.astype(jnp.float32))
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def camformer_paged_attention(
    q: jax.Array,
    kp_pages: jax.Array,
    v_pages: jax.Array,
    k_scale: jax.Array,
    page_table: jax.Array,
    kv_len: jax.Array,
    q_positions: jax.Array,
    spec: AttentionSpec = AttentionSpec(mode="camformer"),
    *,
    window: int | None = None,
    scale: float | None = None,
    impl: str = "fused",
) -> jax.Array:
    """CAMformer attention against a paged, bit-packed KV cache.

    The serving-engine entry point (Eq. 1 over a page-table-indirected Key
    SRAM): binary scores + two-stage top-k select on the paged pools, then
    softmax over the k survivors and a sparse gather of ONLY the selected V
    rows straight out of the paged pool — no per-slot contiguous ``max_len``
    key/value buffer is ever materialized.

    Decode rows (Sq == 1, ``impl="fused"`` — the default) run the fused
    Pallas paged kernel (kernels/bacam_decode.py): scoring + stage-1
    top-k happen page-local via scalar-prefetched page-table DMA and
    only stage-1 candidates reach this level.  Prefill chunks (Sq > 1)
    and ``impl="gather"`` (the selectable XLA reference,
    ``ModelConfig.paged_impl``) gather the packed keys — 1 bit/element,
    6.25% of bf16 — into logical order and run the same two-stage
    selection in XLA.

    Args:
      q: (B, H, Sq, D) queries (GQA: H = G * H_kv).
      kp_pages: (P, H_kv, page, D/32) uint32 packed key pool (one layer).
      v_pages: (P, H_kv, page, Dv) value pool.
      k_scale: (B, H_kv) running per-slot key scale (softmax temperature).
      page_table: (B, NP) int32 logical->physical page map (trash-paged
        rows for unallocated entries).
      kv_len: (B,) int32 valid tokens per slot.
      q_positions: (B, Sq) int32 query positions.

    Returns: (B, H, Sq, Dv) in q's dtype.
    """
    from repro.core.binarize import sign_pm1

    b, h, sq, d = q.shape
    _, hkv, page, dv = v_pages.shape
    g = h // hkv
    np_ = page_table.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    qb = sign_pm1(q.astype(jnp.float32))
    q_scale = jnp.mean(jnp.abs(q.astype(jnp.float32)), axis=-1)  # (B,H,Sq)
    qp = bacam.pack_bits(qb).reshape(b, hkv, g * sq, d // 32)
    kv_len = kv_len.reshape(b).astype(jnp.int32)

    if sq == 1 and impl == "fused":
        # Decode fast path: fused paged scoring + stage-1 top-k kernel.
        from repro.kernels import ops as kops  # local import: no cycle

        cand_v, cand_i = kops.bacam_paged_scores_topk(
            qp, kp_pages, page_table, kv_len,
            q_positions.reshape(b).astype(jnp.int32),
            d=d, group=spec.group_size, stage1_k=spec.stage1_k,
            window=window)
        k_eff = min(spec.k_top, cand_v.shape[-1])
        top_v, sel = jax.lax.top_k(cand_v, k_eff)
        top_i = jnp.take_along_axis(cand_i, sel, axis=-1)
    else:
        # Prefill chunk: gather packed key pages into logical order.
        from repro.kernels.ref import paged_gather_ref

        kp = paged_gather_ref(kp_pages, page_table)  # (B, H_kv, S_log, W)
        scores = bacam.hamming_scores_packed(qp, kp, d)  # (B,Hkv,G*Sq,S)
        kpos = jnp.arange(np_ * page, dtype=jnp.int32)[None, None, None]
        qpos = jnp.broadcast_to(q_positions[:, None, :], (b, hkv, sq))
        qpos = jnp.broadcast_to(qpos[:, :, None, :], (b, hkv, g, sq)).reshape(
            b, hkv, g * sq)[..., None]
        ok = (kpos < kv_len.reshape(b, 1, 1, 1)) & (kpos <= qpos)
        if window is not None:
            ok = ok & (kpos > qpos - window)
        masked = jnp.where(ok, scores.astype(jnp.float32), NEG_INF)
        top_v, top_i = two_stage_topk(
            masked, k=spec.k_top, group_size=spec.group_size,
            stage1_k=spec.stage1_k)

    # --- sparse V contextualization straight from the paged pool ---
    pg = top_i // page  # logical page of each selected key
    row = top_i % page
    phys = page_table[jnp.arange(b)[:, None, None, None], pg]  # (B,Hkv,R,K)
    v_sel = jax.vmap(  # per-kv-head gather: pool is (P, page, Dv) per head
        lambda vh, ph, rh: vh[ph, rh], in_axes=(1, 1, 1), out_axes=1
    )(v_pages, phys, row)  # (B, H_kv, R, K, Dv)

    # per-slot (B, Hkv) or per-query (B, Hkv, Sq) — see
    # binary_paged_attention
    ks = k_scale.astype(jnp.float32)
    if ks.ndim == 2:
        ks = ks[:, :, None]
    ks = jnp.broadcast_to(ks[:, :, None, :], (b, hkv, g, sq))
    temp = (q_scale.reshape(b, hkv, g * sq)
            * ks.reshape(b, hkv, g * sq))[..., None]
    w, _ = topk_softmax_weights(top_v, temp, scale)
    out = jnp.einsum("bhrk,bhrkd->bhrd", w.astype(v_pages.dtype), v_sel)
    return out.reshape(b, h, sq, dv).astype(q.dtype)


def dense_reference(q, k, v, *, causal=True, scale=None, window=None):
    """Naive full-precision softmax attention oracle (tests/teacher)."""
    return attention(
        q, k, v, AttentionSpec(mode="dense"), causal=causal, scale=scale, window=window
    )
