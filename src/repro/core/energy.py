"""CAMformer system simulator: throughput / energy / power / area (Sec. IV).

The paper evaluates CAMformer with "a Python system simulator [that] models
performance, energy, and area" on top of HSPICE-characterized analog blocks
and synthesized digital blocks.  This module is that simulator, rebuilt from
the paper's published structure:

  * 3-stage pipeline (association / normalization / contextualization) with
    fine-grained pipelining inside each stage and coarse-grained pipelining
    across queries; throughput = 1 / max(stage latency)  (Sec. III-C2/3).
  * per-component energies (BA-CAM tile search, SAR ADC conversion, SRAM
    bit access, BF16 MAC, softmax/divider, control) — constants are taken
    from the cited references where given and calibrated so the model
    reproduces the paper's own published aggregates (Table II row, Fig. 8
    breakdown); each constant records its provenance.
  * area from the Fig. 8 breakdown of the 0.26 mm^2 total.

Reproduction targets (BERT-Large, n=1024, d_k=d_v=64, 16 heads, k=32, 1 GHz):
  191 qry/ms, 9045 qry/mJ, 0.26 mm^2, 0.17 W; MHA variant = 16x cores.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "HWConfig",
    "EnergyModel",
    "attention_query_cost",
    "table2_rows",
    "PUBLISHED_BASELINES",
    "energy_vs_m",
]


@dataclasses.dataclass(frozen=True)
class HWConfig:
    """CAMformer core microarchitecture (paper defaults)."""

    freq_hz: float = 1.0e9  # system clock (Table II: "at 1 GHz")
    cam_freq_hz: float = 0.5e9  # BA-CAM search rate (Table I: 500 MHz)
    cam_h: int = 16  # keys per BA-CAM tile
    cam_w: int = 64  # matchline width (bits)
    n_mac: int = 8  # parallel BF16 MACs (Sec. IV-B: "8 parallel MAC units")
    t_div: int = 15  # pipelined BF16 divider latency (Sec. III-C2)
    adc_bits: int = 6
    overhead_cycles: int = 900  # per-query DMA/setup (K stream-in, Q load)
    cores: int = 1  # CAMformer_MHA: 16 cores


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    """Per-op energies (J). Provenance in comments.

    Calibration: with the BERT-Large workload the components below reproduce
    the paper's Fig. 8 shares (V-SRAM 31%, K-SRAM 20%, MAC 26%, BA-CAM 12%,
    rest ~11%) of the Table II total (1/9045 mJ = 110.6 nJ per query).
    """

    # BA-CAM 16x64 tile search incl. matchline charge + precharge  (HSPICE-
    # level block; calibrated to 12% share -> 12.96 pJ per tile search).
    e_cam_tile: float = 12.96e-12
    # 6-bit SAR ADC conversion (ref [39]: 0.95 mW @ 700 MS/s ~ 1.36 pJ/conv;
    # shared-SAR amortization + 45 nm scaling via [42] -> 0.30 pJ effective).
    e_adc_conv: float = 0.30e-12
    # SRAM read energy per bit.  K-SRAM streams wide binary rows (cheap per
    # bit); V-SRAM does random 16b-word reads (expensive per bit).
    e_sram_k_bit: float = 21.1e-15  # calibrated to 20% share
    e_sram_v_bit: float = 65.4e-15  # calibrated to 31% share
    # BF16 MAC (ref [40] scaled to 45 nm via [42]; calibrated to 26% share).
    e_mac_bf16: float = 877.0e-15
    # Softmax LUT lookup + accumulate per selected score (512 B LUT).
    e_softmax_op: float = 1.95e-12
    # Bitonic top-k compare-exchange op.
    e_sort_op: float = 0.32e-12
    # Per-query control/DMA/misc (closes the Fig. 8 budget).
    e_query_ctrl: float = 2.9e-9
    # DRAM energy per bit (paper cites [43]; reported separately — the
    # Table II "Energy Eff." column is accelerator energy, Fig. 8 contains
    # no DRAM slice).
    e_dram_bit: float = 2.33e-12


def attention_query_cost(
    n: int = 1024,
    d_k: int = 64,
    d_v: int = 64,
    heads: int = 16,
    k_top: int = 32,
    group_size: int = 16,
    hw: HWConfig = HWConfig(),
    em: EnergyModel = EnergyModel(),
) -> dict:
    """Latency/energy of one attention query (all heads) on one core.

    Mirrors the paper's pipeline model:
      association:      n/cam_h tile searches, pipelined at the CAM rate;
                        vertical tiling multiplies by d_k/cam_w.
      normalization:    stage-2 refinement across tile batches (n/cam_h
                        candidate insertions) + softmax (k + t_div, Sec.
                        III-C2 pipelined divider).
      contextualization: k * d_v MACs over n_mac parallel units.
    One core processes heads serially; coarse pipelining overlaps stages so
    steady-state cost per head is max(stage latencies) (Sec. III-C3).
    """
    v_tiles = max(1, d_k // hw.cam_w)
    tiles = math.ceil(n / hw.cam_h) * v_tiles
    cam_cycle = hw.freq_hz / hw.cam_freq_hz  # system cycles per CAM search

    cyc_assoc = tiles * cam_cycle
    cyc_norm = math.ceil(n / hw.cam_h) + k_top + hw.t_div
    cyc_ctx = math.ceil(k_top * d_v / hw.n_mac)

    steady = max(cyc_assoc, cyc_norm, cyc_ctx)
    fill = cyc_assoc + cyc_norm  # pipeline fill before first ctx output
    cycles = fill + heads * steady + hw.overhead_cycles
    latency_s = cycles / hw.freq_hz

    # --- energy (per query, all heads) ---
    n_tile_ops = tiles * heads
    n_adc = n_tile_ops * hw.cam_h  # one conversion per matchline readout
    k_bits = n * d_k * heads  # binary K streamed once per query
    v_bits = k_top * d_v * 16 * heads  # BF16 V rows actually fetched
    n_macs = k_top * d_v * heads
    n_sort = (n // group_size) * 2 * math.ceil(math.log2(max(2, 2 * group_size))) * heads
    n_smax = k_top * heads

    e = {
        "bacam": n_tile_ops * em.e_cam_tile,
        "adc": n_adc * em.e_adc_conv,
        "k_sram": k_bits * em.e_sram_k_bit,
        "v_sram": v_bits * em.e_sram_v_bit,
        "mac": n_macs * em.e_mac_bf16,
        "softmax": n_smax * em.e_softmax_op,
        "topk": n_sort * em.e_sort_op,
        "ctrl": em.e_query_ctrl,
    }
    e_total = sum(e.values())
    e_dram = v_bits * em.e_dram_bit  # reported separately (see EnergyModel)

    thr_core = 1.0 / latency_s
    return {
        "cycles": cycles,
        "latency_us": latency_s * 1e6,
        "stage_cycles": {
            "association": cyc_assoc,
            "normalization": cyc_norm,
            "contextualization": cyc_ctx,
        },
        "stage_qps": {  # per-stage standalone throughput (Fig. 9)
            "association": hw.freq_hz / (cyc_assoc * heads),
            "normalization": hw.freq_hz / (cyc_norm * heads),
            "contextualization": hw.freq_hz / (cyc_ctx * heads),
        },
        "throughput_qry_per_ms": thr_core * hw.cores / 1e3,
        "energy_nj_per_query": e_total * 1e9,
        "energy_eff_qry_per_mj": 1e-3 / e_total,
        "energy_breakdown_nj": {k: v * 1e9 for k, v in e.items()},
        "energy_shares": {k: v / e_total for k, v in e.items()},
        "dram_nj_per_query": e_dram * 1e9,
        "dynamic_power_w": e_total * thr_core * hw.cores,
    }


# --- area model (Fig. 8 right: share of the 0.26 mm^2 synthesized total) ---
AREA_TOTAL_MM2 = 0.26
AREA_SHARES = {
    "sram": 0.42,  # Key + Value SRAM
    "top32": 0.26,  # bitonic top-32 + potential-top registers
    "bacam": 0.08,
    "softmax": 0.10,
    "mac": 0.09,
    "ctrl_dma": 0.05,
}


def area_mm2(cores: int = 1) -> dict:
    a = {k: v * AREA_TOTAL_MM2 * cores for k, v in AREA_SHARES.items()}
    a["total"] = AREA_TOTAL_MM2 * cores
    return a


# Published Table II baselines (from the paper; converted footnotes applied).
PUBLISHED_BASELINES = {
    "MNNFast": dict(bits="32/32/32", cores=1, thr_qry_ms=28.4, eff_qry_mj=284, area_mm2=None, power_w=1.00),
    "A3": dict(bits="8/8/8", cores=1, thr_qry_ms=52.3, eff_qry_mj=636, area_mm2=2.08, power_w=0.82),
    "SpAtten_1_8": dict(bits="12/12/12", cores=1, thr_qry_ms=85.2, eff_qry_mj=904, area_mm2=1.55, power_w=0.94),
    "HARDSEA": dict(bits="8/8/8", cores=12, thr_qry_ms=187.0, eff_qry_mj=191, area_mm2=4.95, power_w=0.92),
}

PUBLISHED_CAMFORMER = dict(thr_qry_ms=191.0, eff_qry_mj=9045.0, area_mm2=0.26, power_w=0.17)
PUBLISHED_CAMFORMER_MHA = dict(thr_qry_ms=3058.0, eff_qry_mj=9045.0, area_mm2=4.13, power_w=2.69)
STATIC_POWER_W = 0.149  # total(0.17 W) - dynamic at 191 qry/ms (synthesis leakage + clock)


def table2_rows(n=1024, d_k=64, d_v=64, heads=16, k_top=32) -> dict:
    """Our simulated CAMformer / CAMformer_MHA rows + published baselines."""
    one = attention_query_cost(n, d_k, d_v, heads, k_top, hw=HWConfig(cores=1))
    mha = attention_query_cost(n, d_k, d_v, heads, k_top, hw=HWConfig(cores=16))
    rows = dict(PUBLISHED_BASELINES)
    rows["CAMformer (ours, simulated)"] = dict(
        bits="1/1/16",
        cores=1,
        thr_qry_ms=one["throughput_qry_per_ms"],
        eff_qry_mj=one["energy_eff_qry_per_mj"],
        area_mm2=area_mm2(1)["total"],
        power_w=one["dynamic_power_w"] + STATIC_POWER_W,
    )
    rows["CAMformer_MHA (ours, simulated)"] = dict(
        bits="1/1/16",
        cores=16,
        thr_qry_ms=mha["throughput_qry_per_ms"],
        eff_qry_mj=mha["energy_eff_qry_per_mj"],
        area_mm2=area_mm2(16)["total"],
        power_w=16 * (one["dynamic_power_w"] + STATIC_POWER_W),
    )
    rows["CAMformer (published)"] = dict(bits="1/1/16", cores=1, **PUBLISHED_CAMFORMER)
    rows["CAMformer_MHA (published)"] = dict(bits="1/1/16", cores=16, **PUBLISHED_CAMFORMER_MHA)
    return rows


def energy_vs_m(m_values=(1, 2, 4, 8, 16, 32, 64, 128, 256), em: EnergyModel = EnergyModel()):
    """Fig. 5: per-op energy vs matrix dimension M.

    Programming a CAM tile (writing CAM_H keys) costs ~cam_h * row-write; a
    loaded tile serves M searches, so per-op energy decays as
    E(M) = e_search + e_program / M toward the search-only bound.
    """
    e_program = 16 * 2.0e-12  # write 16 rows (SRAM-cell write + cap precharge)
    e_search = EnergyModel().e_cam_tile
    return {int(m): (e_search + e_program / m) for m in m_values}
