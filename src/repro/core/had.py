"""Hamming Attention Distillation (HAD) — training objective.

CAMformer's accuracy story rests on HAD (paper ref [32]): a student with
binarized Q/K is distilled from a full-precision teacher by matching
attention distributions, keeping <3% top-1 drop.  We implement the
distillation losses so binary-attention models are trainable in this
framework (examples/had_distill.py) and the Tables III/IV mechanism can be
reproduced end-to-end on models we train ourselves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_kl", "row_topk_overlap", "had_loss"]


def attention_kl(teacher_logits, student_logits, mask=None, eps: float = 1e-9):
    """KL(teacher || student) between attention rows, averaged over valid rows.

    Shapes: (..., Sq, Skv) logits; mask broadcastable bool of the same shape
    (False = masked position).
    """
    if mask is not None:
        neg = jnp.asarray(-1e9, teacher_logits.dtype)
        teacher_logits = jnp.where(mask, teacher_logits, neg)
        student_logits = jnp.where(mask, student_logits, neg)
    t = jax.nn.log_softmax(teacher_logits, axis=-1)
    s = jax.nn.log_softmax(student_logits, axis=-1)
    p_t = jnp.exp(t)
    kl = jnp.sum(p_t * (t - s), axis=-1)  # (..., Sq)
    return jnp.mean(kl)


def row_topk_overlap(teacher_logits, student_logits, k: int = 32):
    """Mean overlap of per-row top-k sets (diagnostic for recall@k)."""
    _, ti = jax.lax.top_k(teacher_logits, k)
    _, si = jax.lax.top_k(student_logits, k)
    eq = ti[..., :, None] == si[..., None, :]
    return eq.any(-1).mean()


def had_loss(task_loss, teacher_logits, student_logits, mask=None, alpha: float = 1.0):
    """Total HAD objective: task CE + alpha * attention KL."""
    return task_loss + alpha * attention_kl(teacher_logits, student_logits, mask)
