"""Hierarchical two-stage top-k selection (paper Sec. III-B / III-C4).

Stage 1 keeps the top-``stage1_k`` scores per group of ``group_size`` keys
(the BA-CAM tile height, 16) — in hardware a bitonic top-2 that runs
pipelined with the CAM scan and triggers DMA prefetch of the selected V rows.
Stage 2 finalizes a global top-``k`` (32) over the stage-1 candidates with a
64-input bitonic sorter refined across tile batches.

Functionally stage 2 over candidates is order-equivalent to a top-k over the
candidate *set*; the only approximation vs. single-stage top-k is that a
group contributing more than ``stage1_k`` of the true global top-k loses the
excess — exactly the effect Tables III/IV measure, and bounded by the
Hoeffding recall bound (Sec. III-B1) implemented here.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "NEG_INF",
    "single_stage_topk",
    "two_stage_topk",
    "topk_recall",
    "hoeffding_drop_bound",
]

# Finite "minus infinity" for masked scores: large enough to never be picked
# over any real score (binary scores are in [-d, d], d <= 1024), small enough
# to stay finite in float32/bfloat16 arithmetic.
NEG_INF = -1.0e9


def _masked(scores: jax.Array, where: jax.Array | None) -> jax.Array:
    if where is None:
        return scores
    return jnp.where(where, scores, jnp.asarray(NEG_INF, scores.dtype))


def single_stage_topk(scores: jax.Array, k: int, where: jax.Array | None = None):
    """Plain top-k over the last axis. Returns (values, indices)."""
    s = _masked(scores.astype(jnp.float32), where)
    return jax.lax.top_k(s, k)


@partial(jax.jit, static_argnames=("k", "group_size", "stage1_k"))
def two_stage_topk(
    scores: jax.Array,
    k: int = 32,
    group_size: int = 16,
    stage1_k: int = 2,
    where: jax.Array | None = None,
):
    """Two-stage hierarchical top-k over the last axis.

    Args:
      scores: (..., N) scores (any float/int dtype; compared in float32).
      k: final number of selected keys (paper: 32).
      group_size: stage-1 group (CAM tile height, paper: 16).
      stage1_k: per-group survivors (paper: 2).
      where: optional bool validity mask (..., N); invalid positions are
        never selected (their returned value is NEG_INF).

    Returns:
      (values, indices): (..., k) float32 values and int32 indices into N.
      When fewer than k valid candidates exist, trailing entries have value
      NEG_INF (callers mask them out of the softmax).
    """
    s = _masked(scores.astype(jnp.float32), where)
    *lead, n = s.shape
    pad = (-n) % group_size
    if pad:
        s = jnp.pad(s, [(0, 0)] * len(lead) + [(0, pad)], constant_values=NEG_INF)
    n_pad = n + pad
    groups = n_pad // group_size

    sg = s.reshape(*lead, groups, group_size)
    v1, i1 = jax.lax.top_k(sg, stage1_k)  # (..., G, s1)
    base = (jnp.arange(groups, dtype=jnp.int32) * group_size)[:, None]
    idx1 = i1.astype(jnp.int32) + base  # global indices

    cand_v = v1.reshape(*lead, groups * stage1_k)
    cand_i = idx1.reshape(*lead, groups * stage1_k)

    k_eff = min(k, groups * stage1_k)
    v2, sel = jax.lax.top_k(cand_v, k_eff)
    idx = jnp.take_along_axis(cand_i, sel, axis=-1)
    if k_eff < k:  # degenerate tiny-N case: pad to a static k
        padw = k - k_eff
        v2 = jnp.pad(v2, [(0, 0)] * len(lead) + [(0, padw)], constant_values=NEG_INF)
        idx = jnp.pad(idx, [(0, 0)] * len(lead) + [(0, padw)])
    # Clamp padded-region indices into range (their values are NEG_INF anyway).
    idx = jnp.minimum(idx, n - 1)
    return v2, idx


def topk_recall(selected_idx: jax.Array, true_idx: jax.Array) -> jax.Array:
    """recall@k: fraction of true top-k indices present in the selection.

    Shapes: (..., k) each; returns (...,) float32.
    """
    eq = selected_idx[..., :, None] == true_idx[..., None, :]
    hit = eq.any(axis=-2)  # for each true index: was it selected?
    return hit.mean(axis=-1)


def hoeffding_drop_bound(m: int, delta_min: float, k: int, n: int) -> float:
    """Paper's recall bound:  Pr[drop any true top-k] <= k (N - k) exp(-2 m δ²).

    m: number of Bernoulli matches (= d_k for binary similarity);
    delta_min: minimal normalized score margin around the k-th score;
    k, n: selection size and number of keys.
    """
    return float(min(1.0, k * (n - k) * np.exp(-2.0 * m * delta_min**2)))
