"""HAD-style binarization of attention queries/keys.

Hamming Attention Distillation (HAD, paper ref [32]) binarizes Q and K to
{-1, +1} with a learned/derived per-head scale.  CAMformer consumes the sign
bits (packed into the BA-CAM array); the scale only affects the softmax
temperature, never the *ordering* of scores, so top-k selection is
scale-invariant — this is why the paper can fold the scale into the softmax
LUT.

Training support: ``sign_ste`` is the straight-through estimator used by HAD
so a binarized-attention model remains trainable end-to-end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "sign_pm1",
    "sign_ste",
    "had_scales",
    "binarize_qk",
]


def sign_pm1(x: jax.Array) -> jax.Array:
    """Strict sign into {-1, +1} (zero maps to +1, matching a CAM cell that
    stores a defined bit for every input)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(x.dtype)


@jax.custom_vjp
def sign_ste(x: jax.Array) -> jax.Array:
    """sign(x) in {-1,+1} with a straight-through gradient.

    Backward pass follows HAD / BinaryConnect: pass the gradient through
    unchanged inside the clip region |x| <= 1, zero outside.  This keeps the
    binarized student trainable while the forward pass is exactly what the
    BA-CAM hardware sees.
    """
    return sign_pm1(x)


def _sign_ste_fwd(x):
    return sign_pm1(x), x


def _sign_ste_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_ste_fwd, _sign_ste_bwd)


def had_scales(x: jax.Array, axis: int = -1, keepdims: bool = True) -> jax.Array:
    """Per-vector L1 scale alpha = mean(|x|) (XNOR-Net / HAD analytic scale).

    With q ~= alpha_q * sign(q) and k ~= alpha_k * sign(k), the binary score
    ``s = sign(q) . sign(k)`` approximates ``q.k / (alpha_q * alpha_k)``; the
    product of scales is applied as a softmax temperature downstream.
    """
    return jnp.mean(jnp.abs(x), axis=axis, keepdims=keepdims)


def binarize_qk(
    q: jax.Array,
    k: jax.Array,
    *,
    trainable: bool = False,
    with_scales: bool = True,
):
    """Binarize query/key tensors for the BA-CAM path.

    Args:
      q, k: (..., d) floating tensors.
      trainable: use the straight-through estimator (training) instead of a
        hard sign (inference).
      with_scales: also return the analytic HAD scales.

    Returns:
      (qb, kb) in {-1,+1} with q's dtype, and optionally (q_scale, k_scale)
      with shape (..., 1).
    """
    fn = sign_ste if trainable else sign_pm1
    qb, kb = fn(q), fn(k)
    if not with_scales:
        return qb, kb
    return qb, kb, had_scales(q), had_scales(k)
