"""Architecture configs (assigned pool + the paper's own eval point)."""

from repro.configs import (  # noqa: F401  — registration side effects
    camformer_bert,
    codeqwen15_7b,
    granite_moe_3b,
    llava_next_mistral_7b,
    mistral_nemo_12b,
    moonshot_v1_16b,
    qwen15_110b,
    recurrentgemma_2b,
    rwkv6_3b,
    whisper_medium,
    yi_34b,
)
from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    get_config,
    list_archs,
    smoke_config,
)

ASSIGNED_ARCHS = [
    "whisper-medium",
    "qwen1.5-110b",
    "mistral-nemo-12b",
    "yi-34b",
    "codeqwen1.5-7b",
    "rwkv6-3b",
    "moonshot-v1-16b-a3b",
    "granite-moe-3b-a800m",
    "llava-next-mistral-7b",
    "recurrentgemma-2b",
]
