"""llava-next-mistral-7b [vlm]: Mistral-7B backbone, anyres vision stub
(hf:llava-hf/llava-v1.6-mistral-7b-hf)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    n_patches=576,          # one base-resolution tile (stub embeddings)
    frontend="vision",
    rope_theta=1e6,
))
