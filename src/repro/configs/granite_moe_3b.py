"""granite-moe-3b-a800m [moe]: 40-expert top-8
(hf:ibm-granite/granite-3.0-3b-a800m-base)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,            # d_k = 64: exactly one BA-CAM tile (paper sweet spot)
    d_ff=512,               # per-expert FF width
    vocab=49155,
    n_experts=40,
    experts_per_token=8,
    n_experts_padded=48,    # EP divisibility on the 16-way model axis
))
