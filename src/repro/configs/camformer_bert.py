"""The paper's own evaluation point: BERT-Large attention geometry
(Sec. IV-C: 16 heads, d_k = d_v = 64, n = 1024) with CAMformer attention
(binary Q/K, two-stage Top-32) as the serving configuration."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="camformer-bert",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=30522,
    attn_backend="camformer",
    k_top=32,
    group_size=16,
    stage1_k=2,
))
