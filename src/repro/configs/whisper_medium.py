"""whisper-medium [audio]: enc-dec, conv frontend stubbed (arXiv:2212.04356)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,            # decoder layers
    enc_layers=24,          # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,          # MHA (GQA kv=16)
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    act="gelu",
    norm="layer",
    use_rope=False,
    abs_pos="sinusoidal",
    enc_len=1500,           # 30 s window after conv stride-2 (stub supplies embeddings)
    frontend="audio",
))
