"""recurrentgemma-2b [hybrid]: RG-LRU + local attention 1:2
(arXiv:2402.19427)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,           # MQA
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    act="geglu",
    window=2048,
    layer_pattern=("rglru", "rglru", "attn"),
    rnn_width=2560,
    conv_width=4,
    tie_embeddings=True,
))
