"""Model configuration system.

One frozen dataclass covers every assigned architecture family (dense /
GQA / MoE / SSM / hybrid / enc-dec / VLM); per-arch files instantiate it
with the exact published dimensions and register themselves.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

_REGISTRY: dict = {}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # --- attention / CAMformer integration (first-class feature) ---
    # attn_mode was the seed-era spelling; the alias was deprecated in
    # PR 2-3 and is now REMOVED.  The field survives only so stale
    # replace(attn_mode=...) call sites fail with a clear migration
    # error instead of an opaque TypeError.
    attn_mode: Optional[str] = None  # REMOVED — always None
    # Canonical backend selection (core/backend.py registry names).
    attn_backend: Optional[str] = None
    # Per-layer backend policy: layer i runs layer_backends[i % len] —
    # hybrid models can mix realizations (e.g. sliding-window layers on
    # "dense", full-attention layers on "camformer").  Overrides
    # attn_backend/attn_mode when set.
    layer_backends: Optional[Tuple[str, ...]] = None
    k_top: int = 32
    group_size: int = 16
    stage1_k: int = 2
    use_kernel: bool = False
    # Paged decode realization (serving): "fused" runs each backend's
    # Pallas paged flash/CAM decode kernel (page table as scalar-prefetch
    # operand, streaming softmax — decode bytes/token proportional to
    # LIVE pages); "gather" keeps the XLA page-gather + masked attend as
    # the selectable reference every kernel claim is pinned against.
    paged_impl: str = "fused"
    # Sq > 1 paged realization (chunked-prefill and speculative-verify
    # chunks): "auto" (default) follows paged_impl, so the single switch
    # covers the whole serving path; "fused"/"gather" pin the chunk path
    # independently (the bench's --prefill-impl sweep axis).  CAMformer
    # chunks always gather — there is no fused Sq>1 CAM kernel yet; the
    # "hybrid" backend flash-scores its chunks through the dense pool
    # instead.
    prefill_impl: str = "auto"
    # Distributed CAM search: shard_map the decode-time association stage
    # over the seq-sharded cache — local two-stage top-k per shard, then a
    # tiny candidate all-gather (k values/shard, not N scores) + global
    # top-k + partial-sum contextualization (EXPERIMENTS §Perf H3).
    distributed_topk: bool = False
    # Chunked prefill (serving): process the prompt in chunks of this many
    # tokens, attending to the cache-so-far — bounds prefill activation
    # memory by the chunk instead of the full sequence.  0 = whole-sequence.
    prefill_chunk: int = 0
    # Self-speculative decoding (serving): per tick a drafter stack — the
    # SAME weights run with every layer forced to ``spec_backend`` —
    # proposes spec_k tokens from its own cheap paged cache, and the
    # target stack verifies all k+1 positions in one fused step.
    # 0 disables speculation (token-for-token today's decode loop).
    spec_k: int = 0
    spec_backend: str = "binary"
    # INTERNAL (models/transformer.lm_verify_paged): marks an Sq>1 pass
    # as a speculative VERIFY chunk — stateful backends (binary/camformer
    # k_scale) switch to sequential-decode semantics: per-query running
    # scales, and the chunk's per-position key means stashed for exact
    # accept-prefix rollback.  Never set directly.
    spec_verify: bool = False
    window: Optional[int] = None  # sliding-window layers (hybrid)

    # --- misc transformer knobs ---
    act: str = "silu"  # silu | gelu | geglu
    norm: str = "rms"  # rms | layer
    qkv_bias: bool = False
    rope_theta: float = 1e4
    use_rope: bool = True
    abs_pos: Optional[str] = None  # sinusoidal (whisper) | None
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    n_experts_padded: int = 0  # pad expert axis for EP divisibility (router
    #                            masks pads; e.g. granite 40 -> 48 on a
    #                            16-way model axis)

    # --- hybrid / ssm ---
    layer_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    rnn_width: int = 0  # RG-LRU state width
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # --- encoder-decoder / multimodal frontends (stubs per assignment) ---
    enc_layers: int = 0
    enc_len: int = 0  # fixed encoder length (whisper: 1500 frames)
    frontend: Optional[str] = None  # audio | vision
    n_patches: int = 0  # vision patch embeddings prepended to the sequence

    # --- numerics / compilation ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "full"  # full | none

    def __post_init__(self):
        if self.layer_backends is not None and not self.layer_backends:
            raise ValueError("layer_backends must be a non-empty tuple or "
                             "None (= uniform attn_backend)")
        if self.paged_impl not in ("fused", "gather"):
            raise ValueError(
                f"paged_impl={self.paged_impl!r} must be 'fused' (Pallas "
                "paged decode kernels) or 'gather' (XLA page-gather "
                "reference)")
        if self.prefill_impl not in ("auto", "fused", "gather"):
            raise ValueError(
                f"prefill_impl={self.prefill_impl!r} must be 'auto' "
                "(follow paged_impl), 'fused' (Sq>1 paged flash kernel) "
                "or 'gather' (XLA page-gather reference)")
        if self.spec_k < 0:
            raise ValueError(f"spec_k={self.spec_k} must be >= 0")
        if not self.spec_backend:
            raise ValueError(
                "spec_backend must name an attention backend "
                "(core/backend.py registry name, e.g. 'binary')")
        if self.attn_mode is not None:
            raise ValueError(
                f"attn_mode={self.attn_mode!r} was removed (deprecated in "
                f"PR 2-3); set attn_backend={self.attn_mode!r} instead "
                "(core/backend.py registry name), or layer_backends for a "
                "per-layer policy")

    # --- attention-backend resolution (every consumer goes through
    # these accessors) ---
    @property
    def backend(self) -> str:
        """Resolved default backend name.  A genuinely mixed layer policy
        has no single backend: consumers that cannot thread
        backend_for(layer) (encdec/rglru stacks, dry-run cells) must fail
        loudly rather than silently run every layer on the default."""
        if self.layer_backends:
            uniform = self.uniform_backend
            if uniform is None:
                raise ValueError(
                    "config has a mixed layer_backends policy "
                    f"{self.layer_backends}; use backend_for(layer) / "
                    "backend_names")
            return uniform
        return self.attn_backend or "dense"

    def backend_for(self, layer: int) -> str:
        """Typed accessor: the backend name of one layer (per-layer
        policy cycles layer_backends over the stack, like layer_pattern)."""
        if self.layer_backends:
            return self.layer_backends[layer % len(self.layer_backends)]
        return self.backend

    @property
    def backend_names(self) -> Tuple[str, ...]:
        """Backend name per layer, length n_layers."""
        return tuple(self.backend_for(i) for i in range(self.n_layers))

    @property
    def uniform_backend(self) -> Optional[str]:
        """The single backend name if every layer agrees, else None."""
        names = set(self.backend_names)
        return names.pop() if len(names) == 1 else None

    @property
    def prefill_paged_impl(self) -> str:
        """Effective Sq > 1 (prefill-chunk / verify) paged realization:
        prefill_impl, with "auto" following paged_impl."""
        return self.paged_impl if self.prefill_impl == "auto" \
            else self.prefill_impl

    @property
    def padded_experts(self) -> int:
        return self.n_experts_padded or self.n_experts

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 128 (TPU lanes + mesh divisibility); embedding /
        head params use this width, logits mask the pad columns."""
        return -(-self.vocab // 128) * 128

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str, **overrides) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    cfg = _REGISTRY[name]
    return cfg.replace(**overrides) if overrides else cfg


def list_archs() -> list:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def smoke_config(name: str, **overrides) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests (shape-preserving
    ratios: GQA grouping, MoE top-k, layer pattern are kept)."""
    cfg = get_config(name)
    kv_ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_heads = 4
    n_kv = max(1, n_heads // kv_ratio)
    small = dict(
        n_layers=min(cfg.n_layers, 2 * max(1, len(cfg.layer_pattern) or 1)),
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=32,
        d_ff=96 if cfg.n_experts == 0 else 32,
        vocab=256,
        rnn_width=64 if cfg.rnn_width else 0,
        n_experts=min(cfg.n_experts, 8),
        experts_per_token=min(cfg.experts_per_token, 2),
        enc_layers=min(cfg.enc_layers, 2),
        enc_len=min(cfg.enc_len, 16) if cfg.enc_len else 0,
        n_patches=min(cfg.n_patches, 8) if cfg.n_patches else 0,
        k_top=8,
        group_size=4,
        dtype="float32",
        param_dtype="float32",
    )
    small.update(overrides)
    return cfg.replace(**small)


# Assigned input shapes (seq_len, global_batch) per shape id.
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
