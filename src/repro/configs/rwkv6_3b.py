"""rwkv6-3b [ssm]: Finch, attention-free, data-dependent decay
(arXiv:2404.05892).  CAMformer technique inapplicable (no QK^T) — see
DESIGN.md §Arch-applicability; runs long_500k natively."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,             # d_model / rwkv_head_dim (informational)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    use_rope=False,
))
