"""Logical-axis sharding rules with divisibility-checked fallback chains.

Every parameter / activation / cache tensor carries logical axis names
(models/module.py).  A rule maps a logical axis to an ordered list of mesh
axis candidates (each a mesh-axis name or tuple of names).  Resolution walks
each tensor dimension in order, assigns the first candidate whose mesh size
divides the dimension and whose mesh axes are still free — so e.g. GQA KV
caches with 8 heads on a 16-way `model` axis automatically fall through to
sequence (context) parallelism, and batch=1 long-context decode gives its
axes to the KV sequence dimension.  This is what makes all 40 assigned
(arch x shape) cells resolve without per-cell hand-written specs.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "PARAM_RULES",
    "ACT_RULES",
    "CACHE_RULES",
    "resolve_spec",
    "tree_pspecs",
    "tree_shardings",
    "constrain",
    "set_parallelism_profile",
]

# Candidates may reference axes absent from the current mesh ("pod" on the
# single-pod mesh); absent axes are skipped.
PARAM_RULES = {
    "embed": [("pod", "data"), ("data",)],  # FSDP (ZeRO-3 style)
    "mlp": [("model",)],
    "heads": [("model",)],  # fused n_heads*head_dim projection dim
    "kv_heads": [("model",)],  # fused n_kv*head_dim (divisible even when
    #                            the raw head count is not)
    "vocab": [("model",)],
    "experts": [("model",)],
    "expert_mlp": [("model",)],
    "rnn": [("model",)],
    "layers": [],  # scan axis: never sharded
    "conv": [],
    "head_dim": [],
}

# Serving weights: TP over `model` only, replicated across data (each data
# column serves its own requests) — FSDP gathers would re-stream the full
# weights over ICI every decode step.
SERVE_PARAM_RULES = {
    **{k: v for k, v in PARAM_RULES.items()},
    "embed": [],
}

ACT_RULES = {
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "seq": [],  # sequence kept unsharded in-layer for train/prefill
    # Residual-stream sequence between blocks (Megatron sequence
    # parallelism): layer inputs/outputs + activation checkpoints are
    # seq-sharded over `model`; GSPMD turns the block-boundary TP
    # all-reduces into equal-volume all-gather/reduce-scatter pairs and the
    # per-layer saved activations shrink by the model-axis size.  Recurrent
    # families (rwkv/rglru time scans) do NOT use this axis.
    "res_seq": [("model",)],
    # Attention-interior query sequence: takes `model` ONLY when the head
    # axis could not (heads % model != 0, e.g. yi-34b 56H, granite 24H,
    # recurrentgemma 10H) => sequence parallelism inside attention instead
    # of a partially-sharded contraction that all-reduces the score tensor.
    "att_q_seq": [("model",)],
    "embed": [],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "mlp": [("model",)],
    "vocab": [("model",)],
    "experts": [("model",)],
    "capacity": [("model",)],
    "expert_mlp": [("model",)],
    "rnn": [("model",)],
    "head_dim": [],
}

# KV caches / recurrent states: when batch or heads cannot take the mesh
# axes, the cache sequence dim picks them up => context parallelism.
CACHE_RULES = {
    "batch": [("pod", "data"), ("data",), ("pod",)],
    "kv_heads": [("model",)],
    "heads": [("model",)],
    "kv_seq": [("pod", "data", "model"), ("data", "model"), ("pod", "data"),
               ("data",), ("model",)],
    "head_dim": [],
    "embed": [],
    "rnn": [("model",)],
    "conv": [],
}


_PROFILE = "tp"


def set_parallelism_profile(name: str):
    """Switch the global sharding profile.

    tp (default): Megatron-style — params/activations tensor-sharded over
        `model`, FSDP over `data`, batch over (`pod`,`data`).
    dp: pure data-parallel + ZeRO-3 — batch shards over EVERY axis
        ((`pod`,`data`,`model`)) and params FSDP over the same; because the
        batch/embed dims resolve FIRST and the divisibility resolver skips
        taken axes, every downstream rule (heads/mlp/experts/res_seq/...)
        degrades to local automatically.  This wins whenever per-device
        tokens are small relative to weight reuse (see EXPERIMENTS §Perf:
        granite-3B and qwen-110B train cells).
    """
    global _PROFILE
    if name not in ("tp", "dp"):
        raise ValueError(name)
    _PROFILE = name
    all_axes = [("pod", "data", "model"), ("data", "model")]
    if name == "dp":
        PARAM_RULES["embed"] = list(all_axes) + [("data",)]
        ACT_RULES["batch"] = list(all_axes) + [("data",), ("model",)]
        CACHE_RULES["batch"] = list(all_axes) + [("data",), ("model",)]
    else:
        PARAM_RULES["embed"] = [("pod", "data"), ("data",)]
        ACT_RULES["batch"] = [("pod", "data"), ("data",), ("pod",)]
        CACHE_RULES["batch"] = [("pod", "data"), ("data",), ("pod",)]


def get_parallelism_profile() -> str:
    return _PROFILE


def _mesh_size(mesh: Mesh, axes: Sequence[str]) -> Optional[int]:
    try:
        return math.prod(mesh.shape[a] for a in axes)
    except KeyError:
        return None  # candidate references an axis absent from this mesh


def resolve_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: dict,
) -> P:
    """Resolve logical axes -> PartitionSpec under divisibility fallback."""
    taken: set = set()
    entries = []
    for name, dim in zip(logical_axes, shape):
        assigned = None
        for cand in rules.get(name, ()) if name else ():
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            # drop absent axes from the candidate rather than skipping it
            cand_t = tuple(a for a in cand_t if a in mesh.shape)
            if not cand_t or any(a in taken for a in cand_t):
                continue
            size = _mesh_size(mesh, cand_t)
            if size and dim % size == 0 and dim > 0:
                assigned = cand_t
                taken.update(cand_t)
                break
        if assigned is None:
            entries.append(None)
        elif len(assigned) == 1:
            entries.append(assigned[0])
        else:
            entries.append(assigned)
    return P(*entries)


def tree_pspecs(axes_tree, shapes_tree, mesh: Mesh, rules: dict):
    """Zip an axes tree with a shapes tree into PartitionSpecs."""
    return jax.tree.map(
        lambda ax, sh: resolve_spec(ax, sh.shape, mesh, rules),
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules: dict):
    specs = tree_pspecs(axes_tree, shapes_tree, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]],
              rules: dict = ACT_RULES) -> jax.Array:
    """with_sharding_constraint from logical axes.

    No-op unless an ambient mesh is installed (`jax.set_mesh(mesh)` — done
    by the dry-run / trainer / server launchers); models stay mesh-agnostic.
    """
    from repro.utils import compat

    env = compat.get_abstract_mesh()
    if env is None or not env.shape:  # no mesh context
        return x
    spec = resolve_spec(logical_axes, x.shape, env, rules)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)
