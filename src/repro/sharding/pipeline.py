"""GPipe-style pipeline parallelism (shard_map + collective_permute).

The production dry-run mesh is DP x TP per the assignment, but the
framework supports PP for deeper meshes: layers are split into S stages
along a `pipe` mesh axis; microbatches flow through the stage ring with
`ppermute` handoffs.  A schedule of (n_micro + n_stages - 1) ticks fills
and drains the pipeline; bubble fraction = (S-1)/(M+S-1).

The implementation is deliberately self-contained: `pipeline_forward`
takes a per-stage apply function and stage-stacked params, and is
validated against the sequential oracle in tests/test_pipeline.py on a
4-way host mesh.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils import compat

__all__ = ["pipeline_forward"]


def pipeline_forward(stage_fn, stage_params, x_micro, mesh, axis: str = "pipe"):
    """Run microbatches through a stage ring.

    Args:
      stage_fn: (params_for_stage, h) -> h   (same shape in/out).
      stage_params: pytree with a leading stage axis == mesh.shape[axis].
      x_micro: (n_micro, mb, ...) microbatched input.
      mesh: mesh containing `axis`.

    Returns: (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def body(params_local, x_local):
        # params_local: this stage's params (leading axis stripped by
        # shard_map); x_local: full microbatch stream (replicated).
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        # mark carries as axis-varying up front (their values diverge per
        # stage inside the loop) so the fori carry types stay consistent
        h = compat.pcast(jnp.zeros_like(x_local[0]), (axis,), to="varying")
        outs = compat.pcast(jnp.zeros_like(x_local), (axis,), to="varying")

        def tick(t, carry):
            h, outs = carry
            # stage 0 ingests microbatch t (when available)
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = jax.lax.dynamic_index_in_dim(x_local, mb_idx, 0,
                                                 keepdims=False)
            h_in = jnp.where(stage == 0, fresh, h)
            h_out = stage_fn(params_local, h_in)
            # last stage emits microbatch (t - n_stages + 1); jnp.where
            # instead of lax.cond keeps the shard_map varying-axis types
            # consistent across branches
            out_idx = jnp.clip(t - n_stages + 1, 0, n_micro - 1)
            emit = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            upd = jax.lax.dynamic_update_index_in_dim(outs, h_out, out_idx, 0)
            outs = jnp.where(emit, upd, outs)
            # rotate activations one stage forward
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            h_next = jax.lax.ppermute(h_out, axis, perm)
            return h_next, outs

        h, outs = jax.lax.fori_loop(0, ticks, tick, (h, outs))
        # only the last stage holds real outputs; broadcast them ring-wide
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x_micro)
