"""Gradient compression for cross-pod reduction (int8 + error feedback).

At multi-pod scale the inter-pod links (DCI) are the scarcest bandwidth, so
the cross-pod gradient reduction is compressed: int8 quantization with a
per-tensor scale and an error-feedback accumulator (1-bit-Adam style) that
re-injects quantization residuals the next step — keeping convergence
unbiased in the long run while cutting pod-boundary bytes 4x vs fp32.

Usage (inside a jitted step, via shard_map over the `pod` axis):
    grads, err = compressed_pod_mean(grads, err, axis="pod")
A standalone reference (`compressed_mean_ref`) backs the property tests.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum_leaf",
           "compressed_pod_mean", "compressed_mean_ref"]


def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def compressed_psum_leaf(g: jax.Array, err: jax.Array, axis: str):
    """Error-feedback int8 reduction of one gradient leaf over `axis`.

    int8 payloads are all-gathered together with their per-pod scales and
    dequantized EXACTLY per pod before summation, so the local feedback
    residual x - q*scale telescopes: the time-averaged delivered gradient
    equals the true mean to within max_scale/(2T) (provably unbiased; the
    property test asserts it).  For the pod axis (n small) the int8
    all-gather also moves fewer bytes than an fp32 ring all-reduce:
    (n-1)/n * n * 1 B  vs  2 * 4 B per element.

    Returns (mean gradient f32, new error accumulator)."""
    from repro.utils import compat

    n = compat.axis_size(axis)
    x = g.astype(jnp.float32) + err
    q, scale = quantize_int8(x)
    new_err = x - dequantize_int8(q, scale)  # exact local residual
    qs = jax.lax.all_gather(q, axis)  # (n, ...)
    scales = jax.lax.all_gather(scale, axis)  # (n,)
    shape = (-1,) + (1,) * q.ndim
    total = jnp.sum(qs.astype(jnp.float32) * scales.reshape(shape), axis=0)
    return total / n, new_err


def compressed_pod_mean(grads, err_tree, axis: str = "pod"):
    """Tree version of compressed_psum_leaf (call inside shard_map)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = compressed_psum_leaf(g, e, axis)
        out_g.append(mg)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def compressed_mean_ref(xs, errs):
    """Pure-numpy-style oracle: per-replica quantize w/ feedback, mean.

    xs: (n, ...) stacked replica gradients; errs: same.  Returns
    (mean estimate, new errs) matching compressed_psum_leaf semantics with
    equal scales folded to the mean scale.
    """
    n = xs.shape[0]
    x = xs.astype(jnp.float32) + errs
    scales = jnp.max(jnp.abs(x), axis=tuple(range(1, x.ndim))) / 127.0 + 1e-12
    sc = scales.reshape((-1,) + (1,) * (x.ndim - 1))
    q = jnp.clip(jnp.round(x / sc), -127, 127)
    new_errs = x - q * sc  # exact local residual (per-pod scales)
    return (q * sc).sum(0) / n, new_errs
