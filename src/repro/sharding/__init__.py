"""Distribution: logical-axis partitioning, compression, pipeline."""
