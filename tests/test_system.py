"""End-to-end behaviour tests: trainer fault tolerance (checkpoint/resume,
NaN rollback), serving engine continuous batching, checkpoint atomicity."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_mesh_for
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, SamplingParams, ServeEngine
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainerConfig

SHAPES.setdefault("tiny", dict(seq_len=64, global_batch=4, kind="train"))


def _mk_trainer(tmp_path, steps=8, arch="codeqwen1.5-7b"):
    cfg = smoke_config(arch)
    md = get_model_def(cfg)
    mesh = make_mesh_for(1, 1)
    data = SyntheticLMData(cfg, "tiny", mesh)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=4, log_every=2,
                         ckpt_dir=str(tmp_path / "ckpt"), warmup=2)
    return Trainer(md, cfg, mesh, data, tcfg), cfg, md, mesh


@pytest.mark.slow
def test_trainer_loss_decreases_and_checkpoints(tmp_path):
    trainer, *_ = _mk_trainer(tmp_path, steps=12)
    trainer.run()
    log = trainer.metrics_log
    assert log[-1]["loss"] < log[0]["loss"]
    assert latest_step(trainer.tcfg.ckpt_dir) == 12


@pytest.mark.slow
def test_trainer_resume_continues_from_checkpoint(tmp_path):
    trainer, *_ = _mk_trainer(tmp_path, steps=8)
    trainer.run()
    # second trainer picks up at step 8 and runs to 12
    trainer2, *_ = _mk_trainer(tmp_path, steps=12)
    trainer2.run()
    assert any(ev[1] == "resume" for ev in trainer2.events)
    assert latest_step(trainer2.tcfg.ckpt_dir) == 12


@pytest.mark.slow
def test_trainer_nan_rollback(tmp_path):
    trainer, *_ = _mk_trainer(tmp_path, steps=8)
    trainer.run()

    class PoisonData:
        """Wraps the pipeline; poisons exactly one step after resume."""

        def __init__(self, inner):
            self.inner, self.count = inner, 0

        def batch(self, step):
            b = self.inner.batch(step)
            if self.count == 1:
                b = dict(b)
                b["loss_mask"] = b["loss_mask"] * jnp.nan
            self.count += 1
            return b

    trainer2, *_ = _mk_trainer(tmp_path, steps=12)
    trainer2.data = PoisonData(trainer2.data)
    trainer2.run()
    assert any(ev[1] == "rollback" for ev in trainer2.events)
    assert latest_step(trainer2.tcfg.ckpt_dir) == 12  # still completed


def test_checkpoint_atomic_and_keep_n(tmp_path):
    d = str(tmp_path / "ck")
    state = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    for s in (1, 2, 3, 4):
        save_checkpoint(d, state, s, keep=2)
    assert latest_step(d) == 4
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d)
                   if n.startswith("step_"))
    assert steps == [3, 4]  # keep-N pruned
    got, step = restore_checkpoint(d, state)
    assert step == 4
    assert jnp.allclose(got["a"], state["a"])
    assert not any(n.startswith("tmp_") for n in os.listdir(d))


@pytest.mark.slow
def test_serving_engine_continuous_batching_consistency():
    """Batched engine output == one-request-at-a-time output (greedy)."""
    cfg = smoke_config("codeqwen1.5-7b")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    prompts = [[5, 9, 2], [7, 7, 1, 3, 8], [11, 4], [1, 2, 3, 4, 5, 6]]

    def run(max_batch):
        eng = ServeEngine(md, cfg, params, max_batch=max_batch, max_len=64)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p), sampling=SamplingParams(max_new=6), rid=i))
        done = eng.run()
        return {r.rid: r.tokens for r in done}

    solo = run(1)
    batched = run(3)  # forces slot reuse (4 requests, 3 slots)
    assert solo == batched


@pytest.mark.slow
def test_serving_engine_camformer_mode():
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_backend="camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64)
    for i in range(3):
        eng.submit(Request(prompt=[3 + i, 5, 8], sampling=SamplingParams(max_new=5), rid=i))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.tokens) == 5 for r in done)


def test_data_pipeline_deterministic_and_restart_safe():
    cfg = smoke_config("codeqwen1.5-7b")
    mesh = make_mesh_for(1, 1)
    d1 = SyntheticLMData(cfg, "tiny", mesh, seed=3)
    d2 = SyntheticLMData(cfg, "tiny", mesh, seed=3)
    b1 = d1.batch(17)
    b2 = d2.batch(17)  # fresh pipeline, same step -> same data
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert np.array_equal(np.asarray(b1["labels"]), np.asarray(b2["labels"]))
    # labels are next-token shifted
    assert np.array_equal(np.asarray(b1["labels"])[:, :-1],
                          np.asarray(b1["tokens"])[:, 1:])
    b3 = d1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
