"""Tensor-parallel sharded serving (serving/sharded.py): the identity
matrix and the page-spec sharding contract.

The contract under test: a ``tp > 1`` engine head-shards every page-pool
leaf over a ``("tp",)`` mesh and runs the whole tick shard_map-fused,
yet emits TOKEN-FOR-TOKEN identical streams to the single-device engine
— same events, same tick count, and the sampled ids still the only
per-tick readback (readbacks counter pinned) — because the per-head
attention outputs are reassembled by all_gather (pure concatenation, no
arithmetic) and everything else computes replicated.

Multi-device jax needs the device count fixed before the backend
initializes, so every identity test runs a small script in a subprocess
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the main
pytest process keeps the real single CPU device — see conftest.py).
The dense lane stays in the CI fast lane (no slow mark; this is the
fast lane's forced-host-device --tp 2 configuration); the camformer /
mixed / speculative / preemption matrix is ``slow``.

The spec-derivation unit tests run in-process: they exercise only
``pool_partition_specs`` (pure shape arithmetic over the
``page_spec`` logical-axes tuples), no mesh required.
"""

import os
import subprocess
import sys

import pytest

from repro.configs import smoke_config
from repro.models.transformer import lm_page_specs
from repro.serving import sharded

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_script(body: str, devices: int = 2, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", body], env=env,
                       timeout=timeout, capture_output=True, text=True)
    assert r.returncode == 0, (
        f"exit {r.returncode}\nstdout:\n{r.stdout}\nstderr:\n{r.stderr}")
    return r.stdout


# ---------------------------------------------------------------------------
# spec derivation: every page_spec leaf shards its kv-head axis or raises


@pytest.mark.parametrize("backend,spec_k", [
    ("dense", 0), ("binary", 4), ("camformer", 4), ("hybrid", 4)])
def test_pool_partition_specs_shard_the_head_axis(backend, spec_k):
    """Every leaf of every backend's page_spec (k_pages/v_pages/kp_pages/
    k_scale/k_means) gets "tp" exactly on its kv_heads axis, mechanically
    from the logical-axes tuples — no per-backend case list."""
    cfg = smoke_config("codeqwen1.5-7b").replace(
        attn_backend=backend, spec_k=spec_k)
    specs = lm_page_specs(cfg, n_pages=9, page_size=8, max_batch=2)
    ps = sharded.pool_partition_specs(specs, tp=2)
    assert set(ps) == set(specs)
    for name, (sds, axes) in specs.items():
        assert "kv_heads" in axes, (name, axes)
        dim = axes.index("kv_heads")
        got = tuple(ps[name]) + (None,) * (len(axes) - len(tuple(ps[name])))
        assert got[dim] == "tp", (name, axes, ps[name])
        assert all(a is None for i, a in enumerate(got) if i != dim), (
            name, ps[name])


def test_pool_partition_specs_mixed_stack_structure():
    """Mixed layer_backends policies shard per layer (tuple of per-layer
    spec dicts mirroring the pool tree)."""
    cfg = smoke_config("codeqwen1.5-7b").replace(
        layer_backends=("dense", "camformer"))
    specs = lm_page_specs(cfg, n_pages=9, page_size=8, max_batch=2)
    ps = sharded.pool_partition_specs(specs, tp=2)
    assert isinstance(specs, tuple) and isinstance(ps, tuple)
    assert len(ps) == len(specs)
    for layer_specs, layer_ps in zip(specs, ps):
        assert set(layer_ps) == set(layer_specs)


def test_pool_partition_specs_indivisible_head_axis_raises():
    """tp that does not divide n_kv_heads fails loudly at spec time,
    naming the offending leaf (smoke config has 4 kv heads)."""
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_backend="camformer")
    specs = lm_page_specs(cfg, n_pages=9, page_size=8, max_batch=2)
    with pytest.raises(ValueError, match=r"kv-head axis.*divide.*tp=3"):
        sharded.pool_partition_specs(specs, tp=3)


def test_engine_tp_validation_and_tp1_code_path():
    """tp=1 IS today's engine (no mesh, plain jits — the asserted same
    code path); tp beyond the device count fails with a clear error in
    the single-device main process."""
    import jax

    from repro.models import get_model_def
    from repro.models.module import init_params
    from repro.serving import ServeEngine

    cfg = smoke_config("codeqwen1.5-7b")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                      page_size=8, tp=1)
    assert eng.tp == 1 and eng.mesh is None
    assert eng._pool_pspecs is None  # no shard_map wrapping at tp=1
    with pytest.raises(ValueError, match="devices"):
        ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                    page_size=8, tp=jax.device_count() + 1)
    with pytest.raises(ValueError, match="tp"):
        ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                    page_size=8, tp=0)


# ---------------------------------------------------------------------------
# the identity matrix: tp>1 == tp=1 token for token, readbacks pinned


def identity_script(*, backend=None, layer_backends=None, spec_k=None,
                    shared=0, tp=2, modes=("sync", "overlap"),
                    prefill_slice=None, prefill_impl=None) -> str:
    """A subprocess body that runs the same workload at tp=1 and tp=N
    (each sync and overlap) and asserts identical (rid, index, token)
    event streams with identical readback and tick counters."""
    return f"""
import jax
from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine

cfg = smoke_config("codeqwen1.5-7b")
kw = {{}}
if {layer_backends!r}:
    kw["n_layers"] = max(cfg.n_layers, len({layer_backends!r}))
cfg = cfg.replace(attn_backend={backend!r},
                  layer_backends={layer_backends!r}, **kw)
md = get_model_def(cfg)
params = init_params(md.specs(cfg), jax.random.PRNGKey(0))

def run(tp, mode):
    eng = ServeEngine(md, cfg, params, max_batch=3, max_len=64,
                      page_size=8, mode=mode, tp=tp, spec_k={spec_k!r},
                      prefill_slice={prefill_slice!r},
                      prefill_impl={prefill_impl!r})
    sp = SamplingParams(temperature=0.8, top_k=8, max_new=5)
    pre = list(range(1, {shared} + 1))
    for i in range(4):
        eng.submit(Request(prompt=pre + [3 + i, 5, 8, 1, 9 + i],
                           sampling=sp, rid=i))
    outs = [(o.rid, o.index, o.token) for o in eng.stream()]
    assert eng.mesh is None if tp == 1 else eng.mesh is not None
    return outs, eng.readbacks, eng.ticks

for mode in {modes!r}:
    ref = run(1, mode)
    got = run({tp}, mode)
    assert ref[0] == got[0], (mode, ref[0][:6], got[0][:6])
    assert ref[1:] == got[1:], (mode, ref[1:], got[1:])
    print(mode, "OK", len(ref[0]), "events,", ref[1], "readbacks")
"""


def test_sharded_identity_dense():
    """The fast-lane lane of the acceptance matrix: dense, tp=2, sync +
    overlap, temperature sampling — bit-identical streams, pinned
    readbacks."""
    out = run_script(identity_script(backend="dense"), devices=2)
    assert out.count("OK") == 2, out


@pytest.mark.slow
def test_sharded_identity_camformer_spec_cow():
    """camformer with spec_k=4 drafts AND a COW shared prefix: the
    drafter pool tree shards alongside the target's, speculative
    rollback (truncate_to) and prefix forks run through the same
    shard_map-wrapped one-jitted-copy paths."""
    out = run_script(identity_script(backend="camformer", spec_k=4,
                                     shared=12), devices=2)
    assert out.count("OK") == 2, out


@pytest.mark.slow
def test_sharded_identity_mixed_stack():
    """Mixed dense/camformer layer policy: per-layer pool tuples shard
    leaf-by-leaf and the fused step stays identical."""
    out = run_script(identity_script(layer_backends=("dense", "camformer"),
                                     shared=12), devices=2)
    assert out.count("OK") == 2, out


@pytest.mark.slow
def test_sharded_identity_hybrid_fused_prefill_spec():
    """The hybrid backend at tp=2: the extra dense k_pages leaf shards
    on its kv-head axis like every other pool, fused Sq>1 flash-prefill
    chunks (prefill_slice + COW shared prefix) and CAM spec-verify
    chunks all run shard_map-wide — token-identical to tp=1."""
    out = run_script(identity_script(backend="hybrid", spec_k=3, shared=12,
                                     prefill_slice=8, prefill_impl="fused"),
                     devices=2)
    assert out.count("OK") == 2, out


@pytest.mark.slow
def test_sharded_identity_dense_tp4():
    """Any tp degree, not just 2 (8-device host, tp=4)."""
    out = run_script(identity_script(backend="dense", tp=4), devices=8)
    assert out.count("OK") == 2, out


@pytest.mark.slow
def test_sharded_identity_under_preemption():
    """Page-pressure preemption (tiny pool, priority submit mid-run):
    eviction + recompute-resume replans against ONE host page table and
    stays token-identical on sharded pools.  Mirrors
    test_overlap.test_preemption_equivalence_across_modes."""
    body = """
import jax
from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, RequestState, SamplingParams, ServeEngine

cfg = smoke_config("codeqwen1.5-7b")
md = get_model_def(cfg)
params = init_params(md.specs(cfg), jax.random.PRNGKey(0))

def run(tp):
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                      page_size=8, n_pages=5, prefix_sharing=False,
                      mode="sync", tp=tp)
    lo = Request(prompt=[1, 2, 3, 4, 5, 6],
                 sampling=SamplingParams(max_new=18), rid=0, priority=0)
    eng.submit(lo)
    eng.step()
    eng.step()
    assert lo.state is RequestState.DECODING and len(lo.tokens) >= 2
    hi = Request(prompt=[9, 8, 7, 6, 5, 4],
                 sampling=SamplingParams(max_new=18), rid=1, priority=5)
    eng.submit(hi)
    done = eng.run()  # hi preempts lo, lo resumes via recompute
    assert eng.preemptions >= 1, eng.preemptions
    return {r.rid: tuple(r.tokens) for r in done}, eng.preemptions

ref = run(1)
got = run(2)
assert ref == got, (ref, got)
print("OK", ref[1], "preemptions")
"""
    out = run_script(body, devices=2)
    assert "OK" in out, out
