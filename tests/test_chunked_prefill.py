"""Chunked prefill == whole-sequence prefill.

Dense mode: numerically identical (float tolerance).  CAMformer mode:
binarization (sign) is discontinuous, so different matmul reduction orders
flip borderline bits (|k| ~ 0) and can change top-k tie-breaks; equivalence
is statistical — asserted as <0.5% flipped cache bits and logits cosine
> 0.99 (measured: 0.07% / 0.9977)."""

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params

_IS_LEAF = lambda x: (isinstance(x, tuple) and len(x) == 2
                      and isinstance(x[0], jax.ShapeDtypeStruct))


def _setup(mode):
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_backend=mode)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab,
                              jnp.int32)
    zc = lambda: jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                              md.cache_specs(cfg, 2, 48), is_leaf=_IS_LEAF)
    return cfg, md, params, toks, zc


def test_chunked_prefill_dense_exact():
    cfg, md, params, toks, zc = _setup("dense")
    l1, c1 = md.prefill(params, {"tokens": toks}, zc(), cfg)
    l2, c2 = md.prefill(params, {"tokens": toks}, zc(),
                        cfg.replace(prefill_chunk=8))
    assert float(jnp.abs(l1 - l2).max()) < 1e-3
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert float(jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max()) < 1e-3


def test_chunked_prefill_camformer_statistical():
    cfg, md, params, toks, zc = _setup("camformer")
    l1, c1 = md.prefill(params, {"tokens": toks}, zc(), cfg)
    l2, c2 = md.prefill(params, {"tokens": toks}, zc(),
                        cfg.replace(prefill_chunk=8))
    xor = jnp.bitwise_xor(c1["k_packed"], c2["k_packed"])
    flipped = int(jax.lax.population_count(xor).sum())
    assert flipped / (c1["k_packed"].size * 32) < 0.005
    cos = float(jnp.sum(l1 * l2)
                / (jnp.linalg.norm(l1) * jnp.linalg.norm(l2) + 1e-9))
    assert cos > 0.99


def test_chunked_prefill_then_decode():
    cfg, md, params, toks, zc = _setup("dense")
    cfg = cfg.replace(prefill_chunk=8)
    logits, caches = md.prefill(params, {"tokens": toks}, zc(), cfg)
    tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
    pos = jnp.full((2,), 32, jnp.int32)
    logits2, _ = md.decode(params, tok, pos, pos + 1, caches, cfg)
    assert bool(jnp.isfinite(logits2).all())
