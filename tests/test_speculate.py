"""Self-speculative decoding: unit semantics of the draft/verify pieces
(accept-prefix rule, drafter config, exact ``k_scale`` repair/rollback)
plus engine-level token-for-token identity — ``spec_k > 0`` must emit
EXACTLY the plain decode loop's tokens for dense, camformer, and mixed
target stacks, in sync and overlapped mode, under preemption and COW
prefix sharing, greedy and keyed-sampled alike."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, SamplingParams, ServeEngine
from repro.serving.request import RequestState
from repro.serving.speculate import (accept_prefix, draft_config,
                                     repair_k_scale, select_k_scale)


def _cfg(backend=None, **kw):
    cfg = smoke_config("codeqwen1.5-7b")
    if backend == "mixed":
        return cfg.replace(layer_backends=("dense", "camformer"), **kw)
    if backend is not None:
        kw["attn_backend"] = backend
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# unit: accept-prefix rule


def test_accept_prefix_semantics():
    # columns: [prev_token, d1, d2, d3]; samples are the target's draws
    drafts = jnp.asarray([
        [7, 10, 11, 12],   # all drafts match -> 3 accepted + bonus
        [7, 10, 99, 12],   # d2 mismatches -> 1 accepted + bonus
        [7, 99, 11, 12],   # d1 mismatches -> bonus only
        [7, 10, 99, 12],   # d3 would match but d2 broke the prefix
        [7, 10, 11, 12],   # n_tok=2: only d1 is a real proposal
        [0, 0, 0, 0],      # inert row
    ], jnp.int32)
    samples = jnp.asarray([
        [10, 11, 12, 13],
        [10, 11, 12, 13],
        [10, 11, 12, 13],
        [10, 11, 99, 13],
        [10, 11, 12, 13],
        [0, 0, 0, 0],
    ], jnp.int32)
    n_tok = jnp.asarray([4, 4, 4, 4, 2, 0], jnp.int32)
    got = accept_prefix(drafts, samples, n_tok)
    assert list(np.asarray(got)) == [4, 2, 1, 2, 2, 0]


def test_accept_prefix_single_column_is_plain_decode():
    # m == 1: no proposals at all — every live row emits exactly the one
    # sample (n_valid 1), inert rows 0
    drafts = jnp.asarray([[5], [6]], jnp.int32)
    samples = jnp.asarray([[9], [9]], jnp.int32)
    got = accept_prefix(drafts, samples, jnp.asarray([1, 0], jnp.int32))
    assert list(np.asarray(got)) == [1, 0]


def test_draft_config_forces_uniform_spec_backend():
    cfg = _cfg("mixed", spec_k=3)
    dcfg = draft_config(cfg)
    assert dcfg.layer_backends is None
    assert dcfg.attn_backend == "binary"
    assert set(dcfg.backend_names) == {"binary"}
    # the drafter realization follows spec_backend, not a hardcoded name
    assert draft_config(cfg.replace(spec_backend="camformer")).backend == \
        "camformer"


# ---------------------------------------------------------------------------
# unit: exact k_scale repair / rollback


def _seq_scale(s0, n0, means, upto):
    """The running mean a sequential decode loop would hold after
    accepting ``upto`` of the chunk's keys."""
    return (s0 * n0 + means[..., :upto].sum(-1)) / (n0 + upto)


@pytest.mark.parametrize("stacked", [False, True])
def test_repair_k_scale_reconstructs_sequential_mean(stacked):
    rng = np.random.default_rng(0)
    b, h, m, layers = 4, 2, 3, 2
    shape = (layers, b, h) if stacked else (b, h)
    s0 = jnp.asarray(rng.uniform(0.5, 2.0, shape), jnp.float32)
    means = jnp.asarray(rng.uniform(0.5, 2.0, shape + (m,)), jnp.float32)
    pos = jnp.asarray([10, 10, 10, 0], jnp.int32)
    base = jnp.asarray([0, 4, 0, 0], jnp.int32)
    n_tok = jnp.asarray([3, 3, 3, 0], jnp.int32)
    n_valid = jnp.asarray([2, 1, 3, 0], jnp.int32)
    n0 = (pos - base).astype(jnp.float32)
    if stacked:
        n0 = n0[None, :, None]
        kept_view = lambda v: v[None, :, None]
    else:
        n0 = n0[:, None]
        kept_view = lambda v: v[:, None]
    # the post-verify scale merges ALL n_tok chunk keys (inert row: s0)
    vm = means * (jnp.arange(m) < kept_view(n_tok)[..., None])
    nt = kept_view(n_tok).astype(jnp.float32)
    s1 = jnp.where(nt > 0,
                   (s0 * n0 + vm.sum(-1)) / jnp.maximum(n0 + nt, 1.0), s0)
    new = {"k_scale": s1, "k_means": vm, "other": jnp.zeros(())}
    old = {"k_scale": s0}
    out = repair_k_scale(new, old, pos, base, n_tok, n_valid)
    # rows that rejected a suffix land on the EXACT sequential value ...
    for row, v in enumerate(np.asarray(n_valid)):
        want = (_seq_scale(s0, n0, means, int(v))[..., row, :]
                if int(np.asarray(n_tok)[row]) > int(v)
                else s1[..., row, :])
        np.testing.assert_allclose(np.asarray(out["k_scale"])[..., row, :],
                                   np.asarray(want), rtol=1e-6)
    # ... and nothing-rejected / inert rows keep the post-verify value
    # BIT-exactly (jnp.where select, no recomputation)
    assert (np.asarray(out["k_scale"])[..., 2, :]
            == np.asarray(s1)[..., 2, :]).all()
    assert (np.asarray(out["k_scale"])[..., 3, :]
            == np.asarray(s0)[..., 3, :]).all()
    assert out["other"] is new["other"]  # untouched leaves pass through
    # per-layer tuple trees (unscanned stacks) take the same path
    t = repair_k_scale((new,), (old,), pos, base, n_tok, n_valid)
    assert (np.asarray(t[0]["k_scale"]) == np.asarray(out["k_scale"])).all()
    # layers without a running scale (dense) pass through untouched
    assert repair_k_scale(({"v": s0},), ({"v": s0},), pos, base, n_tok,
                          n_valid)[0]["v"] is s0


def test_select_k_scale_picks_last_accepted_snapshot():
    b, h = 3, 2
    snaps = [jnp.full((b, h), float(j), jnp.float32) for j in range(3)]
    final = {"k_scale": snaps[-1], "pages": jnp.zeros((4,))}
    n_valid = jnp.asarray([3, 1, 0], jnp.int32)
    out = select_k_scale(final, snaps, n_valid)
    # tuple-tree form (snapshot entries are per-layer tuples) agrees
    out_t = select_k_scale((final,), [(s,) for s in snaps], n_valid)[0]
    assert (np.asarray(out_t["k_scale"])
            == np.asarray(out["k_scale"])).all()
    got = np.asarray(out["k_scale"])
    assert (got[0] == 2.0).all()  # fully accepted: last step's scale
    assert (got[1] == 0.0).all()  # one token: first step's scale
    assert (got[2] == 0.0).all()  # inert: snapshot 0 == untouched value
    assert out["pages"] is final["pages"]


# ---------------------------------------------------------------------------
# engine: token-for-token identity vs the plain decode loop


def _generate(md, cfg, params, *, spec_k, mode="sync", prompts, new,
              temp=0.0, top_k=0, **eng_kw):
    eng = ServeEngine(md, cfg, params, max_len=64, page_size=8,
                      mode=mode, spec_k=spec_k, **eng_kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p), rid=i,
                           sampling=SamplingParams(max_new=new,
                                                   temperature=temp,
                                                   top_k=top_k)))
    done = eng.run()
    return {r.rid: r.tokens for r in done}, eng


PROMPTS = [[5, 9, 2], [7, 7, 1, 3, 8, 2, 4], [11, 4, 1, 2, 3]]


def test_spec_greedy_identity_camformer_sync_and_counters():
    """spec_k > 0 with a greedy camformer target emits exactly the plain
    loop's tokens, and the acceptance counters are coherent."""
    cfg = _cfg("camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    plain, p_eng = _generate(md, cfg, params, spec_k=0, prompts=PROMPTS,
                             new=6, max_batch=3)
    spec, s_eng = _generate(md, cfg, params, spec_k=2, prompts=PROMPTS,
                            new=6, max_batch=3)
    assert spec == plain
    # speculation actually ran, and the books are coherent
    assert s_eng.spec_proposed > 0
    assert 0 <= s_eng.spec_accepted <= s_eng.spec_proposed
    assert 0.0 <= s_eng.spec_acceptance <= 1.0
    assert s_eng.spec_acceptance == (s_eng.spec_accepted
                                     / s_eng.spec_proposed)
    # binary drafting its own target accepts nearly everything — if this
    # drops, draft/verify have diverged even though rejection hides it
    assert p_eng.spec_proposed == 0 and p_eng.spec_acceptance == 0.0
    assert s_eng.kv.free_pages == s_eng.kv.n_pages - 1  # all rolled back


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "overlap"])
@pytest.mark.parametrize("backend", ["dense", "camformer", "mixed"])
def test_spec_greedy_identity_matrix(backend, mode):
    """The full target matrix: binary drafts, target verifies — greedy
    outputs are identical to spec_k=0 for every stack, both loop modes."""
    cfg = _cfg(backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    plain, _ = _generate(md, cfg, params, spec_k=0, mode=mode,
                         prompts=PROMPTS, new=6, max_batch=3)
    spec, _ = _generate(md, cfg, params, spec_k=3, mode=mode,
                        prompts=PROMPTS, new=6, max_batch=3)
    assert spec == plain


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["sync", "overlap"])
def test_spec_identity_under_preemption_and_prefix_sharing(mode):
    """Speculation composes with the hard serving paths: page-pressure
    preemption (rollback + recompute resume) and COW prefix sharing
    (slot 3 admitted late against slot 0's registered pages) leave
    greedy outputs token-for-token equal to the plain loop."""
    cfg = _cfg("camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    common = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3]  # > one shared page
    prompts = [common + [11], common + [12], [8, 8, 8]]

    def gen(spec_k):
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64,
                          page_size=8, n_pages=9, mode=mode,
                          spec_k=spec_k)
        lo = Request(prompt=prompts[0], rid=0, priority=0,
                     sampling=SamplingParams(max_new=14))
        eng.submit(lo)
        eng.step()
        eng.step()
        hi = Request(prompt=prompts[1], rid=1, priority=5,
                     sampling=SamplingParams(max_new=14))
        eng.submit(hi)
        eng.submit(Request(prompt=prompts[2], rid=2, priority=0,
                           sampling=SamplingParams(max_new=8)))
        done = eng.run()
        assert {r.rid for r in done} == {0, 1, 2}
        assert lo.state is RequestState.FINISHED
        # drained: every page reclaimable (retained prefixes count —
        # free_pages includes the LRU-retained pool)
        assert eng.kv.free_pages == eng.kv.n_pages - 1
        return {r.rid: r.tokens for r in done}

    assert gen(2) == gen(0)


@pytest.mark.slow
def test_spec_keyed_sampling_identity():
    """Keyed-sample-match acceptance is exact at ANY temperature: the
    emitted tokens are the target's own keyed draws, so a hot-sampled
    speculative run reproduces the plain loop's stream bit-for-bit."""
    cfg = _cfg("binary")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw = dict(prompts=PROMPTS, new=8, temp=0.9, top_k=40, max_batch=3,
              seed=7)
    plain, _ = _generate(md, cfg, params, spec_k=0, **kw)
    spec, s_eng = _generate(md, cfg, params, spec_k=2, **kw)
    assert spec == plain
    assert s_eng.spec_proposed > 0


def test_spec_disabled_engine_is_plain():
    cfg = _cfg("camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                      page_size=8, spec_k=0)
    assert eng.spec_k == 0 and eng.draft_caches is None
    with pytest.raises(ValueError):
        ServeEngine(md, cfg, params, spec_k=-1)
