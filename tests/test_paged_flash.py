"""Fused paged flash-decode (kernels/paged_flash_decode.py) pinned
against the page-gather oracle, kernel-level and through ServeEngine.

Tolerance policy (same-path memory): ``paged_impl="fused"`` vs
``paged_impl="gather"`` share the write path and differ only in the
attend realization, whose dense/binary arithmetic is a softmax over
identical logits — so engine comparisons are TOKEN-FOR-TOKEN exact and
kernel comparisons are float-noise allclose.  The camformer/mixed legs
are marked slow (fused CAM selection vs gathered two-stage top-k)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.attention import (AttentionSpec, attention,
                                  binary_paged_attention)
from repro.core.backend import get_backend
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, RequestState, SamplingParams, ServeEngine

_SLOW = pytest.mark.slow


def _cfg(backend=None, layer_backends=None, **kw):
    cfg = smoke_config("codeqwen1.5-7b")
    if layer_backends:
        kw["n_layers"] = max(cfg.n_layers, len(layer_backends))
    return cfg.replace(attn_backend=backend, layer_backends=layer_backends,
                       **kw)


def _pools(key, b=3, hkv=2, d=32, page=8, np_=4, n_pages=10):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    k_pages = jax.random.normal(k1, (n_pages, hkv, page, d), jnp.float32)
    v_pages = jax.random.normal(k2, (n_pages, hkv, page, d), jnp.float32)
    # live entries point at arbitrary non-trash pages; unallocated
    # entries at the reserved trash page 0 (whose pool rows hold noise)
    pt = jax.random.randint(k3, (b, np_), 1, n_pages).astype(jnp.int32)
    q = jax.random.normal(k4, (b, hkv * 2, 1, d), jnp.float32)
    return q, k_pages, v_pages, pt


def _gather_attend(q, k_pages, v_pages, pt, kv_len, q_pos, window=None):
    """Dense oracle: logical-order gather + standard masked attend."""
    ck = kref.paged_gather_ref(k_pages, pt)
    cv = kref.paged_gather_ref(v_pages, pt)
    kv_pos = jnp.arange(ck.shape[2], dtype=jnp.int32)[None]
    return attention(
        q, ck, cv, AttentionSpec(mode="dense"), causal=True,
        q_positions=q_pos.reshape(-1, 1), kv_positions=kv_pos,
        kv_valid=kv_pos < kv_len.reshape(-1, 1), window=window)


# ---------------------------------------------------------------------------
# kernel level: fused (jnp walk AND Pallas interpreter) == gather oracle


@pytest.mark.parametrize("window", [None, 5])
def test_dense_kernel_matches_gather_oracle_on_edges(window):
    """kv_len exactly on a page boundary, mid-page, == 1, and == 0
    (inert), with trash-paged unallocated table entries."""
    page = 8
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(0), page=page)
    # slot 0: kv_len on the page boundary; slot 1: inert; slot 2: mid-page
    kv_len = jnp.array([2 * page, 0, 21], jnp.int32)
    q_pos = jnp.maximum(kv_len - 1, 0)
    want = _gather_attend(q, k_pages, v_pages, pt, kv_len, q_pos,
                          window=window)
    got = kops.paged_flash_decode(q, k_pages, v_pages, pt, kv_len, q_pos,
                                  window=window)
    live = np.array([0, 2])
    np.testing.assert_allclose(np.asarray(got)[live], np.asarray(want)[live],
                               atol=1e-5)
    # inert row: defined all-zeros output (the gather oracle's inert rows
    # are unspecified — uniform softmax over garbage — so no comparison)
    assert jnp.all(got[1] == 0.0)


def test_interpret_escape_hatch_matches_walk_and_oracle():
    """interpret=True (the Pallas-interpreter CPU debugging hatch) and
    the off-TPU jnp walk share the page sweep and accumulation order."""
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(1))
    kv_len = jnp.array([8, 13, 0], jnp.int32)
    q_pos = jnp.maximum(kv_len - 1, 0)
    walk = kops.paged_flash_decode(q, k_pages, v_pages, pt, kv_len, q_pos)
    kern = kops.paged_flash_decode(q, k_pages, v_pages, pt, kv_len, q_pos,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(walk), atol=1e-6)
    assert jnp.all(kern[2] == 0.0)  # inert contract holds in the kernel too
    want = _gather_attend(q, k_pages, v_pages, pt, kv_len, q_pos)
    np.testing.assert_allclose(np.asarray(kern)[:2], np.asarray(want)[:2],
                               atol=1e-5)


def test_binary_kernel_matches_gather_impl():
    """HAD sign-match scoring: fused in-register K binarization + folded
    temperature == gather impl (sign_pm1 over gathered keys, stored
    k_scale temperature), via binary_paged_attention's two impls."""
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(2))
    b, hkv = pt.shape[0], k_pages.shape[1]
    kv_len = jnp.array([16, 7, 0], jnp.int32)
    q_pos = jnp.maximum(kv_len - 1, 0).reshape(b, 1)
    k_scale = jax.random.uniform(jax.random.PRNGKey(3), (b, hkv)) + 0.5
    outs = {
        impl: binary_paged_attention(
            q, k_pages, v_pages, k_scale, pt, kv_len, q_pos, impl=impl)
        for impl in ("fused", "gather")
    }
    np.testing.assert_allclose(np.asarray(outs["fused"])[:2],
                               np.asarray(outs["gather"])[:2], atol=1e-5)
    # both impls satisfy the inert-row contract (all-zero output)
    assert jnp.all(outs["fused"][2] == 0.0)
    assert jnp.all(outs["gather"][2] == 0.0)


@pytest.mark.parametrize("backend", ["dense", "binary"])
def test_backend_paged_decode_impls_agree_and_share_writes(backend):
    """backend.paged_decode under paged_impl fused vs gather: identical
    pool writes (trash-page routing included) and allclose outputs."""
    cfg = _cfg(backend)
    bk = get_backend(backend)
    b, page, np_, n_pages = 2, 8, 3, 8
    hkv, d, h = cfg.n_kv_heads, cfg.head_dim, cfg.n_heads
    spec = bk.page_spec(cfg, n_pages, page, b, jnp.float32)
    pools = {n: jnp.zeros(sds.shape, sds.dtype)
             for n, (sds, _) in spec.items()}
    pt = jnp.array([[1, 2, 3], [4, 5, 0]], jnp.int32)
    s = 4
    key = jax.random.PRNGKey(4)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, h, s, d), jnp.float32)
    k = jax.random.normal(k2, (b, hkv, s, d), jnp.float32)
    v = jax.random.normal(k3, (b, hkv, s, d), jnp.float32)
    # slot 1's write is right-padded past kv_len: rows land on trash
    pos = jnp.stack([jnp.arange(8, 8 + s), jnp.arange(3, 3 + s)])
    kv_len = jnp.array([8 + s, 5], jnp.int32)

    outs, caches = {}, {}
    for impl in ("fused", "gather"):
        ci = cfg.replace(paged_impl=impl)
        # decode rows (Sq == 1) exercise the fused path; use the last row
        o, c = bk.paged_decode(q[:, :, -1:], pools, k[:, :, -1:],
                               v[:, :, -1:], pos[:, -1:], pt,
                               kv_len, ci)
        outs[impl], caches[impl] = o, c
    np.testing.assert_allclose(np.asarray(outs["fused"]),
                               np.asarray(outs["gather"]), atol=1e-5)
    for name in caches["fused"]:
        assert jnp.array_equal(caches["fused"][name],
                               caches["gather"][name]), name


def test_binary_kscale_updates_and_inert_rows_leave_it_untouched():
    """The binary paged pools carry camformer's running k_scale: valid
    writes update the per-slot mean; kv_len == 0 rows (the fused-step
    inert contract) leave it untouched."""
    cfg = _cfg("binary")
    bk = get_backend("binary")
    b, page, n_pages = 2, 8, 6
    hkv, d = cfg.n_kv_heads, cfg.head_dim
    spec = bk.page_spec(cfg, n_pages, page, b, jnp.float32)
    assert "k_scale" in spec  # the layout addition this PR rides on
    pools = {n: jnp.zeros(sds.shape, sds.dtype)
             for n, (sds, _) in spec.items()}
    prev = pools["k_scale"] + 3.25
    pools["k_scale"] = prev
    s = 4
    k = jax.random.normal(jax.random.PRNGKey(5), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(6), (b, hkv, s, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pt = jnp.array([[1, 2], [3, 4]], jnp.int32)
    kv_len = jnp.array([s, 0], jnp.int32)  # slot 1 inert
    new = bk._paged_write(pools, k, v, pos, pt, kv_len)
    want0 = jnp.mean(jnp.abs(k[0]), axis=(1, 2))
    np.testing.assert_allclose(np.asarray(new["k_scale"][0]),
                               np.asarray(want0), atol=1e-6)
    assert jnp.array_equal(new["k_scale"][1], prev[1])  # inert: untouched
    # and the inert slot's K/V rows all routed to the trash page
    assert jnp.all(new["k_pages"][pt[1]] == 0.0)
    assert jnp.all(new["v_pages"][pt[1]] == 0.0)


# ---------------------------------------------------------------------------
# engine level: fused == gather token-for-token through ServeEngine


def _run_engine(cfg, impl, prompts, *, mode="sync", max_new=5, **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    eng = ServeEngine(md, cfg, params, mode=mode, paged_impl=impl, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p),
                           sampling=SamplingParams(max_new=max_new), rid=i))
    done = {r.rid: r.tokens for r in eng.run()}
    assert eng.kv.free_pages == eng.kv.n_pages - 1  # drained clean
    return done


@pytest.mark.parametrize("backend", ["dense", "binary"])
def test_engine_fused_matches_gather_with_cow_sharing(backend):
    """Token-for-token through the full engine, with a shared prefix
    whose length (12, page_size 8) forces a COW boundary-page fork and
    nonzero sharer offsets — the fork `base` threads through both
    impls identically."""
    cfg = _cfg(backend)
    shared = list(range(30, 42))  # 12 tokens: fork mid-page 2
    prompts = [shared + [i, i + 2] for i in (3, 7)] + [[9, 1, 4], [2, 2]]
    got = {impl: _run_engine(cfg, impl, prompts)
           for impl in ("fused", "gather")}
    assert got["fused"] == got["gather"]
    assert set(got["fused"]) == set(range(len(prompts)))


@pytest.mark.parametrize("mode", [
    "sync", pytest.param("overlap", marks=_SLOW)])
@pytest.mark.parametrize("layer_backends", [
    pytest.param(("dense", "camformer"), marks=_SLOW)])
def test_engine_fused_matches_gather_mixed_stack(mode, layer_backends):
    """A mixed ("dense", "camformer") stack: dense layers flip between
    flash-decode and gather, camformer layers between the CAM kernel and
    the gathered two-stage top-k — token-for-token in both loop modes
    (same-path comparison: only paged_impl differs)."""
    cfg = _cfg(layer_backends=layer_backends)
    shared = list(range(30, 42))
    prompts = [shared + [i, i + 2] for i in (3, 7)] + [[9, 1, 4]]
    got = {impl: _run_engine(cfg, impl, prompts, mode=mode,
                             prefill_slice=8)
           for impl in ("fused", "gather")}
    assert got["fused"] == got["gather"]


def test_engine_fused_matches_gather_under_preemption():
    """Page-pressure preemption (tiny pool): the preempt/resume path and
    its trash-page bookkeeping behave identically under both impls."""
    cfg = _cfg("dense")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))

    def gen(impl):
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                          page_size=8, n_pages=5, prefix_sharing=False,
                          mode="sync", paged_impl=impl)
        lo = Request(prompt=[1, 2, 3, 4, 5, 6],
                     sampling=SamplingParams(max_new=18), rid=0, priority=0)
        eng.submit(lo)
        eng.step()
        eng.step()
        assert lo.state is RequestState.DECODING
        hi = Request(prompt=[9, 8, 7, 6, 5, 4],
                     sampling=SamplingParams(max_new=18), rid=1, priority=5)
        eng.submit(hi)
        done = eng.run()  # hi preempts lo, lo resumes via recompute
        assert {r.rid for r in done} == {0, 1}
        return {r.rid: r.tokens for r in done}

    assert gen("fused") == gen("gather")
