"""Traffic-SLO benchmark harness: report shape, goodput accounting, and
the atomic ``--json`` artifact write (a timed-out CI lane must never
upload a truncated report)."""

import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import serve_slo  # noqa: E402
from repro.utils import write_json_atomic  # noqa: E402


def _args(**kw):
    defaults = dict(
        arch="codeqwen1.5-7b", backend="dense", requests=3, rate=50.0,
        shared_frac=0.5, shared_len=8, max_new=2, max_batch=3, max_len=48,
        page_size=8, n_pages=None, mode="overlap", temperature=0.7, seed=0,
        slo_ttft_ms=60000.0, slo_tpot_ms=60000.0, tp=1, spec_k=None,
        max_queue=None, deadline_ms=None)
    defaults.update(kw)
    import argparse

    return argparse.Namespace(**defaults)


def test_inproc_report_shape_and_smoke_gate(tmp_path):
    args = _args()
    from repro.configs import smoke_config

    workload = serve_slo.build_workload(args, smoke_config(args.arch).vocab)
    assert len(workload) == args.requests
    assert all(w["arrival_s"] > 0 for w in workload)
    assert all(
        len(w["prompt"]) + w["max_new"] <= args.max_len for w in workload)
    # the shared system prompt actually appears in the mix (seeded rng)
    shared = serve_slo._shared_prompt(args)
    assert any(w["prompt"][:len(shared)] == shared for w in workload)

    records, wall, view = serve_slo.drive_inproc(args, workload)
    report = serve_slo.build_report(args, records, wall, view, "inproc")
    for key in serve_slo.REQUIRED_KEYS:
        assert key in report, key
    assert report["completed"] == args.requests
    assert report["cancelled"] == 0
    assert report["goodput_rps"] > 0
    assert report["tokens_per_s"] > 0
    assert report["ttft_ms"]["n"] == args.requests
    assert 0.0 < report["prefix_hit_rate"] < 1.0
    assert report["engine"]["ticks"] > 0
    serve_slo.check_report(report, smoke_ttft_bound_ms=60000.0)

    # the gate actually fires on a violated bound
    with pytest.raises(AssertionError):
        serve_slo.check_report(report, smoke_ttft_bound_ms=1e-9)

    out = tmp_path / "BENCH_slo_dense.json"
    write_json_atomic(out, report)
    assert json.loads(out.read_text())["backend"] == "dense"
    assert not list(tmp_path.glob("*.tmp.*")), "temp file left behind"


def test_write_json_atomic_overwrites(tmp_path):
    p = tmp_path / "r.json"
    write_json_atomic(p, {"a": 1})
    write_json_atomic(p, {"a": 2})
    assert json.loads(p.read_text()) == {"a": 2}
    assert not list(tmp_path.glob("*.tmp.*"))
