"""Production serving API: sampling params (top-p pinned to a numpy
reference), request lifecycle, streamed outputs vs batch run()
(same-path, token-for-token at temperature 0 across backends incl. a
mixed per-layer policy), cancellation, stop tokens, priority preemption,
and copy-on-write prefix sharing (page savings + fork isolation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import (Request, RequestState, SamplingParams,
                           ServeEngine)
from repro.serving import sampler as S

_SLOW = pytest.mark.slow


def _cfg(backend=None, layer_backends=None, **kw):
    cfg = smoke_config("codeqwen1.5-7b")
    if layer_backends:
        kw["n_layers"] = max(cfg.n_layers, len(layer_backends))
    return cfg.replace(attn_backend=backend, layer_backends=layer_backends,
                       **kw)


def _engine(cfg, **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    return ServeEngine(md, cfg, params, **kw)


# ---------------------------------------------------------------------------
# sampling params + samplers


def test_sampling_params_validation():
    SamplingParams(temperature=0.7, top_k=40, top_p=0.9, stop=(1, 2),
                   max_new=4)
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    assert SamplingParams(stop=[3, 4]).stop == (3, 4)  # list coerces


def _np_nucleus_mask(logits, p):
    """Independent numpy reference: per row, walk tokens in (stable)
    descending-probability order, keeping until the cumulative mass
    reaches p; everything else is filtered."""
    e = np.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = e / e.sum(axis=-1, keepdims=True)
    keep = np.zeros(logits.shape, bool)
    for b in range(logits.shape[0]):
        cum = 0.0
        for i in np.argsort(-logits[b], kind="stable"):
            keep[b, i] = True
            cum += probs[b, i]
            if cum >= p:
                break
    return keep


@pytest.mark.parametrize("p", [0.1, 0.5, 0.9, 0.999])
def test_top_p_matches_numpy_reference(p):
    logits = np.asarray(
        jax.random.normal(jax.random.PRNGKey(3), (7, 53)) * 2.0, np.float32)
    got = np.asarray(S.apply_top_p(jnp.asarray(logits), p))
    keep = _np_nucleus_mask(logits, p)
    # kept logits pass through untouched; filtered ones are masked hard
    assert np.array_equal(got > -1e8, keep)
    assert np.allclose(np.where(keep, logits, 0.0),
                       np.where(keep, got, 0.0))
    # the renormalized kept distribution matches the numpy reference
    def norm(v):
        e = np.exp(np.where(keep, v - v.max(-1, keepdims=True), -np.inf))
        return e / e.sum(-1, keepdims=True)
    assert np.allclose(norm(got), norm(logits), atol=1e-6)
    # sampling stays inside the nucleus
    draws = np.asarray(jax.random.categorical(
        jax.random.PRNGKey(5), jnp.asarray(got), axis=-1,
        shape=(64,) + got.shape[:1]))
    assert all(keep[b, t] for row in draws for b, t in enumerate(row))


def test_top_k_and_sample_step_per_row_policies():
    logits = jax.random.normal(jax.random.PRNGKey(4), (5, 31)) * 3.0
    # per-row k: row 0 disabled, others keep exactly k survivors
    ks = jnp.asarray([0, 1, 3, 7, 31])
    masked = np.asarray(S.apply_top_k(logits, ks))
    counts = (masked > -1e8).sum(-1)
    assert list(counts) == [31, 1, 3, 7, 31]
    # sample_step: temperature<=0 rows are greedy regardless of rng;
    # temperature>0 with top_k=1 still pins to the argmax
    temps = jnp.asarray([0.0, 1.0, 0.0, 2.0, 1.5])
    ks = jnp.asarray([0, 1, 5, 1, 0])
    ps = jnp.asarray([1.0, 1.0, 0.9, 1.0, 0.5])
    out = np.asarray(S.sample_step(logits, jax.random.PRNGKey(0), temps, ks,
                                   ps))
    g = np.asarray(S.greedy(logits))
    assert out[0] == g[0] and out[2] == g[2]  # greedy rows
    assert out[1] == g[1] and out[3] == g[3]  # top_k=1 rows
    # top-p row stays inside its own nucleus
    keep = _np_nucleus_mask(np.asarray(logits / 1.5), 0.5)
    assert keep[4, out[4]]


# ---------------------------------------------------------------------------
# lifecycle


def test_request_lifecycle_states_and_scheduler_separation():
    eng = _engine(_cfg())
    req = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=3))
    rid = eng.submit(req)
    assert rid == 0 and req.state is RequestState.QUEUED
    admitted = eng.schedule()  # admission policy alone: no model compute
    assert [a.req for a in admitted] == [req]
    assert req.state is RequestState.PREFILLING
    assert eng.kv.owned(admitted[0].slot)  # pages reserved up front
    events = eng.prefill(admitted)
    assert req.state is RequestState.DECODING
    assert len(events) == 1 and events[0].token == req.tokens[0]
    eng.run()
    assert req.state is RequestState.FINISHED
    assert req.finish_reason == "length" and len(req.tokens) == 3
    assert eng.kv.free_pages == eng.kv.n_pages - 1


def test_submit_validation_and_auto_rid():
    eng = _engine(_cfg())
    assert eng.submit(Request(prompt=[1])) == 0
    assert eng.submit(Request(prompt=[1], rid=7)) == 7
    assert eng.submit(Request(prompt=[1])) == 8  # auto ids skip used ones
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(prompt=[]))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(prompt=[1, 2],
                           sampling=SamplingParams(max_new=63)))


def test_stop_tokens_finish_early():
    eng = _engine(_cfg())
    probe = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=6))
    eng.submit(probe)
    eng.run()
    stop_tok = probe.tokens[2]
    eng2 = _engine(_cfg())
    req = Request(prompt=[5, 9, 2],
                  sampling=SamplingParams(max_new=6, stop=(stop_tok,)))
    eng2.submit(req)
    eng2.run()
    assert req.finish_reason == "stop"
    assert req.tokens == probe.tokens[:3]  # stop token kept in the output
    assert eng2.kv.free_pages == eng2.kv.n_pages - 1


def test_cancel_queued_and_active_frees_pages_immediately():
    eng = _engine(_cfg(), max_batch=1)
    a = Request(prompt=[1, 2, 3], sampling=SamplingParams(max_new=12))
    b = Request(prompt=[4, 5, 6], sampling=SamplingParams(max_new=12))
    eng.submit(a)
    eng.submit(b)
    eng.step()  # a active, b queued
    out = eng.cancel(b.rid)
    assert out.finished and b.state is RequestState.CANCELLED
    n_before = len(a.tokens)
    out = eng.cancel(a.rid)
    assert a.state is RequestState.CANCELLED
    assert out.tokens == tuple(a.tokens) and len(a.tokens) == n_before
    assert eng.kv.free_pages == eng.kv.n_pages - 1  # freed NOW, not at drain
    assert eng.cancel(99) is None
    assert eng.run() == [b, a]  # both surfaced as done, no decode work left


def test_on_token_callback_streams_every_token():
    eng = _engine(_cfg())
    got = {}
    reqs = [Request(prompt=[5, 9, 2 + i],
                    sampling=SamplingParams(max_new=4),
                    on_token=lambda o: got.setdefault(o.rid, []).append(o))
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        outs = got[r.rid]
        assert [o.token for o in outs] == r.tokens
        assert [o.index for o in outs] == [1, 2, 3, 4]
        assert [o.finished for o in outs] == [False, False, False, True]
        assert outs[-1].finish_reason == "length"
        assert outs[-1].tokens == tuple(r.tokens)


# ---------------------------------------------------------------------------
# streaming == batch run (same-path comparison, per decode tolerance policy:
# identical code path -> exact token equality for every backend)


@pytest.mark.parametrize("backend,layer_backends", [
    ("dense", None),
    pytest.param("camformer", None, marks=_SLOW),
    pytest.param(None, ("dense", "camformer"), marks=_SLOW),
])
def test_stream_matches_batch_run_token_for_token(backend, layer_backends):
    cfg = _cfg(backend, layer_backends)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    shared = list(range(30, 42))  # common prefix: exercises COW sharing
    prompts = [shared + [i, i + 2] for i in (3, 7)] + [[9, 1, 4], [2, 2]]
    sp = SamplingParams(max_new=5)

    def reqs():
        return [Request(prompt=list(p), sampling=sp, rid=i)
                for i, p in enumerate(prompts)]

    eng_run = ServeEngine(md, cfg, params, max_batch=3, max_len=64,
                          page_size=8)
    for r in reqs():
        eng_run.submit(r)
    want = {r.rid: r.tokens for r in eng_run.run()}

    eng_stream = ServeEngine(md, cfg, params, max_batch=3, max_len=64,
                             page_size=8)
    got = {}
    finished = {}
    for out in eng_stream.stream(*reqs()):
        got.setdefault(out.rid, []).append(out.token)
        finished[out.rid] = out.finished
    assert got == want  # token-for-token at temperature 0
    assert all(finished.values())
    assert eng_stream.kv.free_pages == eng_stream.kv.n_pages - 1


def test_per_request_sampling_policies_in_one_batch():
    eng = _engine(_cfg())
    greedy = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=6))
    hot = Request(prompt=[5, 9, 2],
                  sampling=SamplingParams(temperature=1.2, top_k=11,
                                          top_p=0.9, max_new=4))
    short = Request(prompt=[7, 1], sampling=SamplingParams(max_new=1))
    for r in (greedy, hot, short):
        eng.submit(r)
    eng.run()
    assert len(greedy.tokens) == 6 and len(hot.tokens) == 4
    assert len(short.tokens) == 1  # finished at prefill
    ref = _engine(_cfg())
    solo = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=6))
    ref.submit(solo)
    ref.run()
    assert greedy.tokens == solo.tokens  # hot neighbor never perturbs greedy
    assert all(0 <= t < eng.cfg.vocab for t in hot.tokens)


# ---------------------------------------------------------------------------
# COW prefix sharing


def test_prefix_sharing_saves_pages_and_keeps_tokens_identical():
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    system = list(range(40, 60))  # 20 tokens: 2 full pages + 4-row tail
    prompts = [system + [i, i + 1] for i in (3, 7, 11)]
    sp = SamplingParams(max_new=5)

    def gen(share):
        eng = ServeEngine(md, cfg, params, max_batch=4, max_len=64,
                          page_size=8, prefix_sharing=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p), sampling=sp, rid=i))
        done = eng.run()
        assert eng.kv.free_pages == eng.kv.n_pages - 1
        return {r.rid: r.tokens for r in done}, eng.peak_pages

    want, peak_independent = gen(False)
    got, peak_shared = gen(True)
    assert got == want  # aliased pages hold identical KV (dense: exact)
    assert peak_shared < peak_independent


def test_cow_fork_mutation_leaves_sibling_decode_unchanged():
    """Mutate one fork's page contents mid-flight: the request owning the
    fork goes off the rails, its sibling (sharing the ancestor pages)
    decodes exactly as an unmutated control engine — proof the fork is a
    private copy, not an alias."""
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    system = list(range(40, 60))
    pa, pb = system + [3, 4], system + [7, 8]
    sp = SamplingParams(max_new=8)

    def engines():
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64,
                          page_size=8)
        a = Request(prompt=list(pa), sampling=sp, rid=0)
        eng.submit(a)
        eng.step()  # a prefilled + 1 decode; its pages are now matchable
        b = Request(prompt=list(pb), sampling=sp, rid=1)
        eng.submit(b)
        eng.step()  # b admitted: shares 2 full pages, forks the boundary
        return eng, a, b

    eng, a, b = engines()
    slot_a = eng.active.index(a)
    slot_b = eng.active.index(b)
    assert b.prefix_matched == 20
    t = eng.kv.table
    assert list(t[slot_a, :2]) == list(t[slot_b, :2])  # aliased full pages
    fork_page = int(t[slot_b, 2])
    assert fork_page != int(t[slot_a, 2])
    ctrl, ctrl_a, ctrl_b = engines()

    # clobber the fork page across every layer's pools
    eng.caches = jax.tree.map(
        lambda x: (x.at[:, fork_page].set(jnp.ones_like(x[:, fork_page]))
                   if x.ndim >= 2 and x.shape[1] == eng.kv.n_pages else x),
        eng.caches)
    eng.run()
    ctrl.run()
    assert a.tokens == ctrl_a.tokens  # sibling decode unchanged
    assert b.tokens != ctrl_b.tokens  # the mutation was really read


def test_prefix_offsets_keep_padding_writes_off_live_pages():
    """Regression: with a prefix match, padded prefill rows sit at
    positions offset+j which can run PAST max_len (the suffix buckets up
    to a multiple of PREFILL_BUCKET).  Those rows must spill to the
    trash page — clamped page-table indexing would alias them onto the
    slot's LAST page and corrupt live KV rows (order-undefined duplicate
    scatter), flipping the victim's decoded tokens."""
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    system = list(range(100, 120))  # 20 shared tokens
    unique = list(range(10, 50))  # 40 more: plen 60, max_new 4 -> all 8 pages
    sp = SamplingParams(max_new=4)

    def gen(share):
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64,
                          page_size=8, prefix_sharing=share)
        a = Request(prompt=list(system) + [1, 2], sampling=sp, rid=0)
        eng.submit(a)
        eng.step()  # materialize the shared prefix pages
        b = Request(prompt=system + unique, sampling=sp, rid=1)
        eng.submit(b)
        eng.run()
        return b

    b_shared = gen(True)
    assert b_shared.prefix_matched == 20  # offsets active: padding rows
    #                                       landed at positions 68..
    b_plain = gen(False)
    assert b_shared.tokens == b_plain.tokens


def test_prefix_sharing_defers_same_tick_duplicates():
    """Two identical prompts submitted together: the second must NOT read
    pages whose prefill has not run; it admits one tick later and then
    aliases the materialized pages."""
    eng = _engine(_cfg(), max_batch=2)
    a = Request(prompt=[5, 6, 7, 8, 9, 10, 11, 12, 13],
                sampling=SamplingParams(max_new=4))
    b = Request(prompt=[5, 6, 7, 8, 9, 10, 11, 12, 13, 14],
                sampling=SamplingParams(max_new=4))
    eng.submit(a)
    eng.submit(b)
    eng.step()
    assert a.state is RequestState.DECODING
    assert b.state is RequestState.QUEUED  # deferred, not starved
    eng.step()
    assert b.state is RequestState.DECODING
    assert b.prefix_matched > 0
    eng.run()
    assert len(a.tokens) == 4 and len(b.tokens) == 4
    assert eng.kv.free_pages == eng.kv.n_pages - 1


# ---------------------------------------------------------------------------
# preemption


def test_page_pressure_preempts_lowest_priority_decoder():
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    # 4 usable pages x 8 tokens; each request needs 3 pages
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32, page_size=8,
                      n_pages=5, prefix_sharing=False)
    lo = Request(prompt=[1, 2, 3, 4, 5, 6],
                 sampling=SamplingParams(max_new=18), rid=0, priority=0)
    eng.submit(lo)
    eng.step()
    eng.step()
    assert lo.state is RequestState.DECODING
    hi = Request(prompt=[9, 8, 7, 6, 5, 4],
                 sampling=SamplingParams(max_new=18), rid=1, priority=5)
    eng.submit(hi)
    eng.step()
    # the high-priority request evicted lo: pages released, tokens kept
    assert hi.state is RequestState.DECODING
    assert lo.state is RequestState.QUEUED
    kept_tokens = list(lo.tokens)
    assert len(kept_tokens) >= 2
    done = eng.run()  # lo resumes (re-prefills prompt+generated) and finishes
    assert {r.rid for r in done} == {0, 1}
    assert all(len(r.tokens) == 18 for r in done)
    # resume continued FROM the kept tokens, it did not restart generation
    assert lo.tokens[:len(kept_tokens)] == kept_tokens
    assert eng.kv.free_pages == 4


def test_equal_priority_never_preempts():
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32, page_size=8,
                      n_pages=5, prefix_sharing=False)
    a = Request(prompt=[1, 2, 3, 4], sampling=SamplingParams(max_new=8),
                rid=0)
    eng.submit(a)
    eng.step()
    b = Request(prompt=[5, 6, 7, 8], sampling=SamplingParams(max_new=8),
                rid=1)
    eng.submit(b)
    eng.step()
    assert a.state is RequestState.DECODING  # FIFO peer waits instead
    done = eng.run()
    assert {r.rid for r in done} == {0, 1}
