"""Multi-device tests (sharded == unsharded equivalence, elastic rescale,
pipeline parallelism, compressed all-reduce).

These REQUIRE virtual devices, and the device count must be set before jax
initializes — so each test runs a small script in a subprocess with
--xla_force_host_platform_device_count (the main pytest process keeps the
real single CPU device, per the project rules).
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

# every test here spawns a fresh multi-device jax subprocess
pytestmark = pytest.mark.slow


def run_script(body: str, devices: int = 8, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", body], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import smoke_config
from repro.configs.base import SHAPES
from repro.models import get_model_def
from repro.models.module import init_params
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import make_train_step, state_specs
from repro.train.data import SyntheticLMData

SHAPES["tiny"] = dict(seq_len=64, global_batch=8, kind="train")
cfg = smoke_config("granite-moe-3b-a800m").replace(n_experts_padded=8)
md = get_model_def(cfg)

from repro.utils import compat

def run(mesh):
    compat.set_mesh(mesh)
    step, opt = make_train_step(md, cfg, warmup=1)
    sds, shard = state_specs(md, cfg, mesh)
    params = jax.jit(lambda k: init_params(md.specs(cfg), k),
                     out_shardings=shard["params"])(jax.random.PRNGKey(0))
    state = {"params": params, "opt": jax.jit(opt.init, out_shardings=shard["opt"])(params)}
    data = SyntheticLMData(cfg, "tiny", mesh, seed=1)
    with mesh:
        jstep = jax.jit(step, in_shardings=(shard, None))
        losses = []
        for i in range(3):
            state, m = jstep(state, data.batch(i))
            losses.append(float(m["loss"]))
    return losses

l1 = run(make_mesh_for(1, 1))
l8 = run(make_mesh_for(8, 2))
print("single:", l1)
print("sharded:", l8)
assert all(abs(a - b) / abs(a) < 5e-3 for a, b in zip(l1, l8)), (l1, l8)
print("OK")
""")


def test_elastic_rescale_bit_identical():
    run_script("""
import jax, jax.numpy as jnp, tempfile
from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.launch.mesh import make_mesh_for
from repro.launch.steps import state_specs
from repro.launch.elastic import rescale_state, verify_rescale
from repro.train.checkpoint import save_checkpoint

cfg = smoke_config("codeqwen1.5-7b")
md = get_model_def(cfg)
mesh_a = make_mesh_for(8, 4)
sds, shard = state_specs(md, cfg, mesh_a)
params = jax.jit(lambda k: init_params(md.specs(cfg), k),
                 out_shardings=shard["params"])(jax.random.PRNGKey(0))
state = {"params": params, "opt": {"m": params, "v": params,
                                   "step": jnp.zeros((), jnp.int32)}}
d = tempfile.mkdtemp()
save_checkpoint(d, state, 7)
# restore onto a DIFFERENT mesh shape (2-way model instead of 4-way)
mesh_b = make_mesh_for(8, 2)
state_b, step = rescale_state(d, md, cfg, mesh_b)
assert step == 7
assert verify_rescale(state, state_b)
print("OK")
""")


def test_pipeline_parallelism_matches_sequential():
    run_script("""
import jax, jax.numpy as jnp
from repro.launch.mesh import make_mesh_for
from repro.sharding.pipeline import pipeline_forward

from repro.utils import compat
mesh = compat.make_mesh((4,), ("pipe",), axis_types=compat.axis_type_auto(1))
S, MB, D = 4, 3, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (S, D, D)) / D**0.5

def stage(w, h):
    return jnp.tanh(h @ w)

x = jax.random.normal(jax.random.PRNGKey(1), (5, MB, D))  # 5 microbatches
out = pipeline_forward(stage, ws, x, mesh, axis="pipe")

# sequential oracle
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ ws[s])
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err
print("OK")
""", devices=4)


def test_compressed_allreduce_shard_map():
    run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.sharding.compression import compressed_psum_leaf, compressed_mean_ref

from repro.utils import compat
mesh = compat.make_mesh((4,), ("pod",), axis_types=compat.axis_type_auto(1))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))  # per-pod grads
errs = jnp.zeros_like(g)

def f(g_local, e_local):
    m, ne = compressed_psum_leaf(g_local[0], e_local[0], "pod")
    return m[None], ne[None]

fn = jax.jit(compat.shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                              out_specs=(P("pod"), P("pod"))))
mean_est, new_err = fn(g, errs)
ref_mean, ref_err = compressed_mean_ref(g, errs)
# every pod computed the same mean estimate; matches the reference exactly
est0 = np.asarray(mean_est)[0]
assert np.allclose(np.asarray(mean_est), est0[None], atol=1e-5)
assert np.allclose(est0, np.asarray(ref_mean), atol=1e-4)
# error feedback: the TIME-AVERAGED estimate converges to the true mean
true = np.asarray(g).mean(0)
acc = np.zeros(64)
errs_t = errs
steps = 60
for _ in range(steps):
    est, errs_t = fn(g, errs_t)
    acc += np.asarray(est)[0]
drift = np.abs(acc / steps - true).max()
assert drift < 0.05, drift
print("OK")
""", devices=4)


def test_production_mesh_shapes():
    run_script("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
assert m2.size == 512
print("OK")
""", devices=512)


def test_distributed_camformer_matches_local():
    """H3 (EXPERIMENTS §Perf): shard_map CAM search == single-device path."""
    run_script("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import smoke_config
from repro.core.backend import get_backend
from repro.core import bacam, sign_pm1
from repro.launch.mesh import make_mesh_for

from repro.utils import compat
mesh = make_mesh_for(4, 2)  # data=2, model=2
compat.set_mesh(mesh)
cfg = smoke_config("codeqwen1.5-7b", head_dim=128, n_heads=4,
                   n_kv_heads=2).replace(attn_backend="camformer", k_top=8,
                                         group_size=4, stage1_k=2)
bk = get_backend(cfg.backend)
B, HKV, H, S, D = 1, 2, 4, 64, 128
k_raw = jax.random.normal(jax.random.PRNGKey(3), (B, HKV, S, D))
cache = {
    "k_packed": bacam.pack_bits(sign_pm1(k_raw)),
    "v": jax.random.normal(jax.random.PRNGKey(1), (B, HKV, S, D)),
    "k_scale": jnp.mean(jnp.abs(k_raw), axis=(2, 3)),
}
q = jax.random.normal(jax.random.PRNGKey(2), (B, H, 1, D))
pos = jnp.full((B, 1), 40, jnp.int32)
kvl = jnp.full((B,), 41, jnp.int32)
with mesh:
    local = jax.jit(lambda q, c: bk._cache_attend(
        q, c, kvl, pos, cfg))(q, cache)
    sh = NamedSharding(mesh, P(None, None, ("data", "model"), None))
    cache_sh = dict(cache)
    cache_sh["k_packed"] = jax.device_put(cache["k_packed"], sh)
    cache_sh["v"] = jax.device_put(cache["v"], sh)
    dist = jax.jit(lambda q, c: bk._distributed_attend(
        q, c, kvl, pos, cfg))(q, cache_sh)
err = float(jnp.abs(local - dist).max())
assert err < 1e-4, err
print("OK")
""", devices=4)
