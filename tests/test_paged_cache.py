"""Paged KV cache: allocator churn, packed-key round-trip, fused paged
kernel vs oracle, decode-vs-prefill logit consistency, and engine
equivalence under page pressure — the engine-level tests run as a
backend matrix (dense bf16 pages vs camformer bit-packed pages) against
the contiguous-cache reference of the same backend."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import bacam
from repro.core.binarize import sign_pm1
from repro.core.topk import NEG_INF
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, SamplingParams, ServeEngine
from repro.serving.kv_cache import TRASH_PAGE, PagedKVCache, pages_for
from repro.serving.scheduler import RejectionError

_IS_LEAF = lambda x: (isinstance(x, tuple) and len(x) == 2
                      and isinstance(x[0], jax.ShapeDtypeStruct))


def _zeros(specs):
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        specs, is_leaf=_IS_LEAF)


def _cam_cfg(**kw):
    return smoke_config("codeqwen1.5-7b").replace(attn_backend="camformer",
                                                  **kw)


def _cfg_for(backend, **kw):
    return smoke_config("codeqwen1.5-7b").replace(attn_backend=backend, **kw)


# ---------------------------------------------------------------------------
# allocator


def test_allocator_churn_conserves_pages():
    rng = np.random.default_rng(0)
    kv = PagedKVCache(n_pages=33, page_size=16, max_batch=6,
                      max_pages_per_seq=8)
    total = kv.free_pages
    live = {}
    for it in range(300):
        slot = int(rng.integers(0, 6))
        if slot in live and rng.random() < 0.4:
            kv.release(slot)
            del live[slot]
            continue
        n_tok = int(rng.integers(1, 8 * 16 + 1))
        need = pages_for(n_tok, 16)
        have = len(kv.owned(slot))
        if kv.can_reserve(n_tok, slot):
            kv.reserve(slot, n_tok)
            live[slot] = n_tok
            assert len(kv.owned(slot)) == max(need, have)
        # invariants after every op
        owned = [p for s in range(6) for p in kv.owned(s)]
        assert TRASH_PAGE not in owned  # trash page never handed out
        assert len(set(owned)) == len(owned)  # no double allocation
        assert kv.free_pages + len(owned) == total
        # table rows mirror ownership; unowned entries are trash
        for s in range(6):
            o = kv.owned(s)
            assert list(kv.table[s, :len(o)]) == o
            assert (kv.table[s, len(o):] == TRASH_PAGE).all()
    for s in list(live):
        kv.release(s)
    assert kv.free_pages == total


def test_allocator_release_unowned_is_loud():
    """release() of a slot that owns nothing is an allocator-accounting
    bug (double release / never-reserved slot) and must raise, not
    silently no-op."""
    kv = PagedKVCache(n_pages=9, page_size=8, max_batch=4,
                      max_pages_per_seq=4)
    with pytest.raises(ValueError, match="owns no pages"):
        kv.release(0)  # never reserved
    with pytest.raises(ValueError, match="unknown slot"):
        kv.release(7)  # out of range
    kv.reserve(0, 10)
    kv.release(0)
    with pytest.raises(ValueError, match="owns no pages"):
        kv.release(0)  # double release
    assert kv.free_pages == kv.n_pages - 1


def test_allocator_release_while_shared_keeps_pages_live():
    """Refcounted release: a shared page survives its first owner's
    release and is freed only when the LAST owner releases it."""
    kv = PagedKVCache(n_pages=9, page_size=4, max_batch=3,
                      max_pages_per_seq=4)
    prompt = list(range(10))  # 2 full pages + 2-row tail
    kv.reserve(0, len(prompt) + 2)
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    m = kv.match_prefix(prompt + [77])
    assert m.matched == 10 and len(m.shared) == 2 and m.fork_src is not None
    forks = kv.reserve_shared(1, m, 13)
    assert forks == [(kv.owned(0)[2], kv.owned(1)[2])]
    shared = kv.owned(0)[:2]
    assert kv.owned(1)[:2] == shared
    assert all(kv.page_refs[p] == 2 for p in shared)
    total_used = kv.used_pages
    kv.release(0)  # sharer keeps the prefix pages alive
    assert all(kv.page_refs[p] == 1 for p in shared)
    assert kv.used_pages == total_used - 1  # only slot 0's private tail page
    with pytest.raises(ValueError, match="owns no pages"):
        kv.release(0)  # double release after a shared release
    # the surviving owner can still be matched against
    m2 = kv.match_prefix(prompt[:8] + [1, 2, 3])
    assert m2.matched == 8 and tuple(m2.shared) == tuple(shared)
    kv.release(1)
    assert kv.free_pages == kv.n_pages - 1  # retained pages ARE reclaimable
    # prefix RETENTION: the drained registry stays matchable (LRU pool)
    assert kv.retained_pages == 3
    m3 = kv.match_prefix(prompt + [77])
    assert m3.matched == 10 and tuple(m3.shared) == tuple(shared)
    # ... unless retention is disabled: then the registry is swept
    kv2 = PagedKVCache(n_pages=9, page_size=4, max_batch=3,
                       max_pages_per_seq=4, retain_prefixes=False)
    kv2.reserve(0, len(prompt))
    kv2.register_prefix(0, prompt)
    kv2.commit_prefixes()
    kv2.release(0)
    assert kv2.retained_pages == 0
    assert kv2.match_prefix(prompt + [77]).matched == 0  # registry swept


def test_allocator_churn_with_sharing_conserves_pages():
    """Allocator-churn regression over the refcount/COW surface: random
    reserve / shared-reserve / release cycles never leak or double-free
    pages, and page_refs always equals the number of owning slots."""
    rng = np.random.default_rng(1)
    kv = PagedKVCache(n_pages=25, page_size=4, max_batch=5,
                      max_pages_per_seq=6)
    total = kv.free_pages
    prompts = {}
    for it in range(400):
        slot = int(rng.integers(0, 5))
        if slot in prompts:
            if rng.random() < 0.5:
                kv.release(slot)
                del prompts[slot]
        else:
            plen = int(rng.integers(1, 15))
            first = int(rng.integers(0, 3))  # small alphabet: real overlaps
            prompt = [first] + list(map(int, rng.integers(0, 3, plen - 1)))
            need = plen + 4
            m = kv.match_prefix(prompt)
            if m.defer or not kv.can_reserve(need, slot,
                                             n_shared=len(m.shared)):
                continue
            kv.reserve_shared(slot, m, need)
            kv.register_prefix(slot, prompt)
            kv.commit_prefixes()
            prompts[slot] = prompt
        # invariants after every op
        refs = np.zeros(kv.n_pages, np.int64)
        for s in range(5):
            for p in kv.owned(s):
                refs[p] += 1
        assert (refs == kv.page_refs).all()
        assert refs[TRASH_PAGE] == 0
        unique = {p for s in range(5) for p in kv.owned(s)}
        assert kv.free_pages + len(unique) == total
        for s in range(5):  # table rows mirror ownership
            o = kv.owned(s)
            assert list(kv.table[s, :len(o)]) == o
            assert (kv.table[s, len(o):] == TRASH_PAGE).all()
    for s in list(prompts):
        kv.release(s)
    assert kv.free_pages == total


def test_retention_lru_evicts_oldest_under_pressure():
    """Refcount-0 registered pages are retained (matchable) and evicted
    LRU-first when the free list runs dry; unregistered pages are never
    retained."""
    kv = PagedKVCache(n_pages=6, page_size=4, max_batch=2,
                      max_pages_per_seq=4)  # 5 usable
    prompt = list(range(8))  # exactly 2 pages
    kv.reserve(0, 8)
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    kv.release(0)
    assert kv.retained_pages == 2
    assert kv.free_pages == 5  # retained pages count as reclaimable
    assert kv.match_prefix(prompt + [9]).matched == 8  # both pages shared
    # allocating 4 pages: 3 off the free list + 1 LRU eviction (the chain
    # HEAD was released first, so it evicts first and breaks the match)
    kv.reserve(1, 16)
    assert kv.retained_pages == 1
    assert kv.match_prefix(prompt + [9]).matched == 0
    kv.release(1)
    assert kv.free_pages == 5  # conservation across retention churn


def test_prefix_retention_reuses_drained_prefix():
    """ISSUE 4 satellite regression: a DRAINED engine still serves its
    registered system prompt — a resubmitted shared-prefix request
    revives the retained pages (zero new prefix-page allocations) and
    decodes exactly as a fresh engine would (dense: exact)."""
    cfg = _cfg_for("dense")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    system = list(range(40, 60))  # 20 tokens: 2 full pages + 4-row tail
    sp = SamplingParams(max_new=4)

    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8)
    eng.submit(Request(prompt=system + [1, 2], sampling=sp, rid=0))
    eng.run()
    assert not eng.has_work
    # drained: 2 full prefix pages + the registered tail page retained
    assert eng.kv.retained_pages == 3
    retained = list(eng.kv._retained)

    b = Request(prompt=system + [9, 9], sampling=sp, rid=1)
    eng.submit(b)
    adm = eng.schedule()
    assert adm[0].matched == 20  # full pages + the 4 registered tail rows
    owned = eng.kv.owned(adm[0].slot)
    assert owned[:2] == retained[:2]  # revived, NOT newly allocated
    assert adm[0].forks[0][0] == retained[2]  # boundary page COW-forks
    eng.prefill(adm)
    eng.run()
    assert len(b.tokens) == 4

    # the retention-served generation matches a cold engine exactly
    ctrl = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8)
    cb = Request(prompt=system + [9, 9], sampling=sp, rid=1)
    ctrl.submit(cb)
    ctrl.run()
    assert b.tokens == cb.tokens


def test_allocator_reserve_is_idempotent_and_bounded():
    kv = PagedKVCache(n_pages=5, page_size=8, max_batch=2,
                      max_pages_per_seq=4)
    kv.reserve(0, 17)  # 3 pages
    pages = kv.owned(0)
    kv.reserve(0, 10)  # shrink request: no-op
    assert kv.owned(0) == pages
    assert not kv.can_reserve(8 * 4 + 1)  # check-then-reserve never raises
    with pytest.raises(ValueError):
        kv.reserve(0, 8 * 4 + 1)  # beyond max_pages_per_seq
    kv.reserve(1, 8)
    with pytest.raises(MemoryError):
        kv.reserve(1, 8 * 3)  # pool exhausted (4 usable pages)


# ---------------------------------------------------------------------------
# packed-key round-trip through the paged write path


def test_paged_write_roundtrips_packed_keys():
    cfg = _cam_cfg()
    md = get_model_def(cfg)
    B, page, n_pages, npseq = 2, 8, 9, 4
    pools = _zeros(md.page_specs(cfg, n_pages, page, B))
    kv = PagedKVCache(n_pages, page, B, npseq)
    lens = [13, 5]
    for b in range(B):
        kv.reserve(b, lens[b])
    pt = jnp.asarray(kv.table)

    from repro.core.backend import get_backend
    hkv, d = cfg.n_kv_heads, cfg.head_dim
    s = 16
    k = jax.random.normal(jax.random.PRNGKey(1), (B, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, hkv, s, d))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (B, s))
    layer0 = jax.tree.map(lambda a: a[0], pools)
    new = get_backend("camformer")._paged_write(
        layer0, k, v, pos, pt, jnp.asarray(lens, jnp.int32), cfg)

    want = bacam.pack_bits(sign_pm1(k))  # (B, hkv, s, W) — binarize layout
    got = kref.paged_gather_ref(new["kp_pages"], pt)  # (B, hkv, NP*page, W)
    gotv = kref.paged_gather_ref(new["v_pages"], pt)
    for b in range(B):
        n = lens[b]
        assert jnp.array_equal(got[b, :, :n], want[b, :, :n]), b
        assert jnp.allclose(gotv[b, :, :n], v[b, :, :n]), b
    # per-slot k_scale == mean |k| over the VALID tokens only
    for b in range(B):
        ref = jnp.mean(jnp.abs(k[b, :, :lens[b]]), axis=(1, 2))
        assert jnp.allclose(new["k_scale"][b], ref, atol=1e-6), b


# ---------------------------------------------------------------------------
# fused paged kernel vs jnp oracle


@pytest.mark.parametrize("window", [None, 20])
def test_paged_topk_kernel_matches_oracle(window):
    rng = np.random.default_rng(3)
    B, HKV, R, d, page, P, NP = 3, 2, 4, 64, 32, 20, 4
    W = d // 32
    qp = jnp.asarray(rng.integers(0, 2**32, (B, HKV, R, W), dtype=np.uint32))
    kp = jnp.asarray(rng.integers(0, 2**32, (P, HKV, page, W),
                                  dtype=np.uint32))
    pt = jnp.asarray(
        rng.permutation(P - 1)[:B * NP].reshape(B, NP) + 1, jnp.int32)
    kvl = jnp.asarray([1, 37, NP * page], jnp.int32)
    # default decode tail AND an explicit mid-sequence query position
    for qpos in (None, jnp.asarray([0, 11, 60], jnp.int32)):
        args = (qp, kp, pt, kvl) if qpos is None else (qp, kp, pt, kvl, qpos)
        v, i = kops.bacam_paged_scores_topk(
            *args, d=d, group=16, stage1_k=2, window=window)
        rv, ri = kref.bacam_paged_topk_ref(
            qp, kp, pt, kvl, d, q_pos=qpos, group_size=16, stage1_k=2,
            window=window)
        rvf = jnp.where(rv <= kref.MASKED_SCORE // 2, NEG_INF,
                        rv.astype(jnp.float32))
        assert jnp.array_equal(v, rvf)
        valid = rvf > NEG_INF / 2
        assert jnp.array_equal(jnp.where(valid, i, 0),
                               jnp.where(valid, ri, 0))


# ---------------------------------------------------------------------------
# decode-vs-prefill logit consistency (camformer mode, paged cache)


@pytest.mark.parametrize("backend", ["dense", "camformer"])
@pytest.mark.parametrize("chunk,plen", [(0, 9), (4, 8)])
def test_paged_decode_consistent_with_prefill(backend, chunk, plen):
    """Decode of the last prompt token == one-shot prefill logits, for
    both the whole-prompt and the chunked (lax.scan) prefill branch."""
    cfg = _cfg_for(backend, prefill_chunk=chunk)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    prompt = list(map(int,
                      np.random.default_rng(5).integers(0, cfg.vocab, plen)))
    page, n_pages = 8, 9

    def fresh():
        pools = _zeros(md.page_specs(cfg, n_pages, page, 1))
        kv = PagedKVCache(n_pages, page, 1, 4)
        kv.reserve(0, len(prompt) + 2)
        return pools, jnp.asarray(kv.table)

    # one-shot prefill of the whole prompt
    pools, pt = fresh()
    full, _ = md.prefill_paged(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None],
                 "lens": jnp.asarray([len(prompt)], jnp.int32)},
        pools, pt, cfg)
    # prefill of prompt[:-1], then decode prompt[-1] at its position
    pools, pt = fresh()
    _, pools = md.prefill_paged(
        params, {"tokens": jnp.asarray(prompt[:-1], jnp.int32)[None],
                 "lens": jnp.asarray([len(prompt) - 1], jnp.int32)},
        pools, pt, cfg)
    stepped, _ = md.decode_paged(
        params, jnp.asarray([prompt[-1]], jnp.int32),
        jnp.asarray([len(prompt) - 1], jnp.int32),
        jnp.asarray([len(prompt)], jnp.int32), pools, pt, cfg)
    # same tolerance as the seed's dense decode-vs-prefill test (bf16 noise)
    assert float(jnp.abs(full - stepped).max()) < 2e-2


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["dense", "camformer"])
def test_paged_engine_matches_contiguous_reference(backend):
    """Backend-equivalence matrix: greedy generations through the paged
    engine (slot churn, batched prefill, paged decode) == the seed-era
    contiguous-cache path of the SAME backend driven one request at a
    time, token-for-token at temperature 0.  For ``dense`` this pins the
    new dense-paged layout to the seed dense reference."""
    cfg = _cfg_for(backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    prompts = [[5, 9, 2], [7, 7, 1, 3, 8, 2, 4], [11, 4], [1, 2, 3, 4, 5]]
    new = 6

    # reference: seed contiguous-cache prefill/decode, batch of one
    def reference(p):
        dc = _zeros(md.cache_specs(cfg, 1, 64))
        logits, dc = md.prefill(
            params, {"tokens": jnp.asarray(p, jnp.int32)[None]}, dc, cfg)
        toks = [int(jnp.argmax(logits[0]))]
        pos = len(p)
        for _ in range(new - 1):
            logits, dc = md.decode(
                params, jnp.asarray([toks[-1]], jnp.int32),
                jnp.asarray([pos], jnp.int32),
                jnp.asarray([pos + 1], jnp.int32), dc, cfg)
            toks.append(int(jnp.argmax(logits[0])))
            pos += 1
        return toks

    want = {i: reference(p) for i, p in enumerate(prompts)}

    # paged engine with 3 slots (forces slot reuse) and a page pool sized
    # to HALF full residency (forces admission backpressure via pages)
    eng = ServeEngine(md, cfg, params, max_batch=3, max_len=64, page_size=8,
                      n_pages=1 + 3 * 4)
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p), sampling=SamplingParams(max_new=new), rid=i))
    done = eng.run()
    got = {r.rid: r.tokens for r in done}
    assert got == want
    assert eng.kv.free_pages == eng.kv.n_pages - 1  # everything released


@pytest.mark.parametrize("backend", ["dense", "camformer"])
def test_paged_engine_page_pressure_queues_and_completes(backend):
    # chunked prefill on (prompts longer than the chunk hit the scan path)
    cfg = _cfg_for(backend, prefill_chunk=8)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    # pool of 4 usable pages x 8 tokens; requests need 2-3 pages ->
    # only a subset of the 4 requests can be resident at once
    eng = ServeEngine(md, cfg, params, max_batch=4, max_len=32, page_size=8,
                      n_pages=5)
    prompts = [[3, 5, 8, 1], [4, 5, 8, 1],
               [5, 5, 8, 1, 9, 2, 7, 7, 3, 1],  # > chunk: chunked prefill
               [6, 5, 8, 1]]
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=p, sampling=SamplingParams(max_new=8), rid=i))
    done = eng.run()
    assert len(done) == 4
    assert all(len(r.tokens) == 8 for r in done)
    assert eng.kv.free_pages == 4


def test_paged_engine_single_token_request():
    cfg = _cam_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32, page_size=8)
    eng.submit(Request(prompt=[1, 2, 3], sampling=SamplingParams(max_new=1), rid=0))
    with pytest.raises(ValueError):
        eng.submit(Request(prompt=[], sampling=SamplingParams(max_new=4), rid=1))
    done = eng.run()
    assert len(done) == 1 and len(done[0].tokens) == 1  # exactly max_new


def test_paged_engine_oversized_request_raises():
    """A request that can NEVER fit the page pool is rejected at submit
    (admission control: RejectionError, a ValueError subclass) instead of
    poisoning the queue until a mid-serve MemoryError."""
    cfg = _cam_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8,
                      n_pages=3)  # 2 usable pages = 16 tokens
    req = Request(prompt=[1, 2, 3], sampling=SamplingParams(max_new=30), rid=0)
    with pytest.raises(RejectionError, match="pool has 2"):
        eng.submit(req)
    assert not eng.queue  # never enqueued; the engine keeps serving


# ---------------------------------------------------------------------------
# page-leak regressions (ISSUE 10): every path ends with kv.check()
# balancing free + retained + used == n_pages - 1


def test_cancel_mid_prefill_releases_pages():
    """Cancelling a request WHILE its chunked prefill is in flight (some
    chunks materialized, more planned) must release every reserved page
    and leave the registry sound — the classic mid-admission leak."""
    cfg = _cam_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8,
                      prefill_slice=8)
    req = Request(prompt=list(range(1, 25)),  # 24 tokens: 3+ chunk ticks
                  sampling=SamplingParams(max_new=4), rid=0)
    eng.submit(req)
    eng.poll()  # admission + FIRST chunk only
    assert req.state.name == "PREFILLING"
    assert eng.kv.used_pages > 0
    out = eng.cancel(0)
    assert out is not None and out.finish_reason == "cancelled"
    eng.run()  # drain any in-flight tick
    eng.kv.check()
    assert eng.kv.used_pages == 0
    assert not eng.has_work and not eng.has_pending


def test_preempt_then_cancel_balances_pool():
    """A preempted (re-queued, tokens kept) request that is then
    cancelled must not resurrect or leak its released pages; the winner
    decodes to completion and the pool balances."""
    cfg = _cfg_for("dense")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    # 4 usable pages; low needs 2, high needs 3 -> admission preempts low
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32, page_size=8,
                      n_pages=5)
    low = Request(prompt=[3, 5, 8, 1], sampling=SamplingParams(max_new=8),
                  rid=0, priority=0)
    eng.submit(low)
    eng.poll()
    eng.poll()  # low is DECODING (evictable) with tokens accumulated
    high = Request(prompt=list(range(2, 12)),
                   sampling=SamplingParams(max_new=8), rid=1, priority=1)
    eng.submit(high)
    while eng.preemptions == 0 and (eng.has_work or eng.has_pending):
        eng.poll()
        eng.kv.check()
    assert eng.preemptions >= 1 and low in eng.queue
    out = eng.cancel(0)  # cancel the preempted request while queued
    assert out is not None and out.finish_reason == "cancelled"
    eng.run()
    eng.kv.check()
    assert eng.kv.used_pages == 0
    assert high.finish_reason == "length" and len(high.tokens) == 8
    assert low.finish_reason == "cancelled"


def test_cow_fork_then_truncate_balances():
    """COW-fork a shared prefix, truncate the sharer INTO the shared
    page (boundary fork), then release everything: refcounts, registry
    claims, and the free/retained split must balance at every step."""
    kv = PagedKVCache(n_pages=8, page_size=8, max_batch=2,
                      max_pages_per_seq=4)
    prompt = list(range(16))  # 2 full pages
    kv.reserve(0, 16)
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    kv.check()
    m = kv.match_prefix(prompt + [7, 7, 7])
    kv.reserve_shared(1, m, 24)  # 2 aliased pages + 1 private
    kv.check()
    forks = kv.truncate_to(1, 12)  # cut INTO the second shared page
    assert len(forks) == 1
    kv.check()
    kv.release(1)
    kv.check()
    kv.release(0)  # registered pages retire to the RETAINED pool
    kv.check()
    assert kv.used_pages == 0
    assert kv.free_pages == kv.n_pages - 1  # retained pages reclaimable


# ---------------------------------------------------------------------------
# truncate_to (speculative-decode rollback)


def test_truncate_across_page_boundary_releases_pages():
    """Rolling a slot back across a page boundary releases the pages
    wholly beyond the keep point (reusable immediately, LIFO order) and
    trashes their table entries; the kept prefix is untouched."""
    kv = PagedKVCache(n_pages=12, page_size=8, max_batch=2,
                      max_pages_per_seq=6, retain_prefixes=False)
    total = kv.free_pages
    kv.reserve(0, 40)  # 5 pages
    owned = kv.owned(0)
    assert len(owned) == 5
    forks = kv.truncate_to(0, 19)  # keep 3 pages, boundary row 3
    assert forks == []  # private boundary page: nothing to fork
    assert kv.owned(0) == owned[:3]
    assert list(kv.table[0, :3]) == owned[:3]
    assert (kv.table[0, 3:] == TRASH_PAGE).all()
    assert kv.free_pages == total - 3
    # regrow after rollback: the released pages come straight back in
    # their original order (allocator LIFO), so the slot looks exactly
    # as it did before the speculative overshoot
    kv.reserve(0, 40)
    assert kv.owned(0) == owned


def test_truncate_into_cow_shared_page_forks_never_writes():
    """Truncating INTO a COW-aliased prefix page must fork it: the slot
    gets a private copy (returned as a copy job) and the shared original
    — still another slot's live KV — is never written or remapped."""
    kv = PagedKVCache(n_pages=8, page_size=8, max_batch=2,
                      max_pages_per_seq=4, retain_prefixes=False)
    prompt = list(range(16))  # exactly 2 full pages
    kv.reserve(0, 16)
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    pages0 = kv.owned(0)
    m = kv.match_prefix(prompt + [7, 7, 7])
    assert m.matched == 16 and list(m.shared) == pages0
    kv.reserve_shared(1, m, 24)  # 2 aliased pages + 1 private
    shared_pg = kv.owned(1)[1]
    assert shared_pg == pages0[1] and kv.page_refs[shared_pg] == 2
    forks = kv.truncate_to(1, 12)  # cut into the SECOND shared page
    assert len(forks) == 1
    src, dst = forks[0]
    assert src == shared_pg and dst != src
    assert kv.owned(1) == [pages0[0], dst]
    assert kv.table[1, 1] == dst and kv.table[1, 2] == TRASH_PAGE
    # the original is still slot 0's private page, registry intact
    assert kv.page_refs[src] == 1 and kv.page_refs[dst] == 1
    assert kv.owned(0) == pages0
    m2 = kv.match_prefix(prompt + [9])
    assert m2.matched == 16 and list(m2.shared) == pages0
    # double-truncate idempotence: the boundary page is private now, so
    # truncating to the same length again is a pure no-op
    table = kv.table.copy()
    assert kv.truncate_to(1, 12) == []
    assert (kv.table == table).all()
    assert kv.owned(1) == [pages0[0], dst]


def test_truncate_into_shared_page_refuses_when_pool_exhausted():
    """When no page can back the boundary fork, truncate_to must refuse
    (MemoryError) rather than hand the slot a shared page to write."""
    kv = PagedKVCache(n_pages=3, page_size=8, max_batch=2,
                      max_pages_per_seq=2)
    prompt = list(range(16))
    kv.reserve(0, 16)  # both usable pages
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    m = kv.match_prefix(prompt + [0])
    assert m.matched == 16
    kv.reserve_shared(1, m, 16)  # aliases both pages, pool now empty
    before = kv.owned(1)
    with pytest.raises(MemoryError):
        kv.truncate_to(1, 12)
    # refusal left the mapping intact and the page still safely shared
    assert kv.owned(1) == before
    assert kv.page_refs[before[1]] == 2


def test_truncate_retained_prefix_sharer_updates_registry():
    """Truncating the sharer of a registered prefix: pages it releases
    that are still registered go back to the RETAINED pool (matchable
    later), while registry claims over boundary-page rows the slot is
    about to rewrite are dropped so hash matching stays sound."""
    kv = PagedKVCache(n_pages=10, page_size=8, max_batch=2,
                      max_pages_per_seq=5)  # retain_prefixes=True
    prompt = list(range(24))  # 3 full pages
    kv.reserve(0, 24)
    kv.register_prefix(0, prompt)
    kv.commit_prefixes()
    a, b_, c = kv.owned(0)
    free0 = len(kv._free)
    forks = kv.truncate_to(0, 10)  # keep page a + rows 0-1 of page b_
    assert forks == []  # sole owner: no fork needed
    assert kv.owned(0) == [a, b_]
    # page c was registered + materialized -> retained, NOT freed
    assert kv.retained_pages == 1 and len(kv._free) == free0
    # the full-page chain claim on b_ is stale (rows 2+ will be
    # rewritten): a fresh prompt now matches only the first page
    m = kv.match_prefix(prompt + [99])
    assert m.matched == 8 and list(m.shared) == [a]
    # ... and the surviving first-page entry is genuinely attachable
    kv.reserve_shared(1, m, 12)
    assert kv.owned(1)[0] == a and kv.page_refs[a] == 2


def test_truncate_validates_and_truncate_to_zero_releases_all():
    kv = PagedKVCache(n_pages=10, page_size=8, max_batch=1,
                      max_pages_per_seq=5, retain_prefixes=False)
    kv.reserve(0, 30)
    with pytest.raises(ValueError):
        kv.truncate_to(0, -1)
    with pytest.raises(ValueError):
        kv.truncate_to(3, 0)  # unknown slot
    snap = kv.owned(0)
    assert kv.truncate_to(0, 13) == []
    assert kv.owned(0) == snap[:2]
    # idempotent: same length again changes nothing
    table = kv.table.copy()
    assert kv.truncate_to(0, 13) == []
    assert (kv.table == table).all() and kv.owned(0) == snap[:2]
    # truncate to zero = full rollback; every page is reusable again
    assert kv.truncate_to(0, 0) == []
    assert kv.owned(0) == []
    assert (kv.table[0] == TRASH_PAGE).all()
    assert kv.free_pages == kv.n_pages - 1
