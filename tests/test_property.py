"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (binary_scores_exact, pack_bits, sign_pm1,
                        single_stage_topk, topk_recall, two_stage_topk,
                        unpack_bits)
from repro.core.bacam import adc_readout, hamming_scores_packed
from repro.sharding.compression import compressed_mean_ref
from repro.sharding.partitioning import resolve_spec

SETTINGS = settings(max_examples=25, deadline=None)


@given(st.integers(1, 4), st.integers(1, 6), st.sampled_from([32, 64, 96, 128]),
       st.integers(0, 2**31 - 1))
@SETTINGS
def test_pack_is_bijective_and_scores_bounded(b, r, d, seed):
    x = sign_pm1(jax.random.normal(jax.random.PRNGKey(seed), (b, r, d)))
    y = sign_pm1(jax.random.normal(jax.random.PRNGKey(seed + 1), (b, r, d)))
    assert (unpack_bits(pack_bits(x), d) == x).all()
    s = hamming_scores_packed(pack_bits(x), pack_bits(y), d)
    assert (s == binary_scores_exact(x, y)).all()
    assert int(jnp.abs(s).max()) <= d
    # parity invariant: s == d (mod 2)
    assert (((s - d) % 2) == 0).all()


@given(st.integers(0, 2**31 - 1), st.integers(2, 64), st.sampled_from([4, 8, 16]),
       st.integers(1, 3))
@SETTINGS
def test_two_stage_topk_invariants(seed, n_groups, group, s1):
    n = n_groups * group
    k = min(32, n)
    scores = jax.random.normal(jax.random.PRNGKey(seed), (2, n))
    tv, ti = two_stage_topk(scores, k=k, group_size=group, stage1_k=s1)
    # 1) returned values are the scores at returned indices
    picked = jnp.take_along_axis(scores, ti, axis=-1)
    valid = tv > -1e8
    assert jnp.allclose(jnp.where(valid, picked, 0), jnp.where(valid, tv, 0))
    # 2) values sorted descending
    assert (jnp.diff(tv, axis=-1) <= 1e-6).all()
    # 3) no duplicate indices among valid entries
    for row_i, row_v in zip(np.asarray(ti), np.asarray(valid)):
        sel = row_i[row_v]
        assert len(set(sel.tolist())) == len(sel)
    # 4) superset property: with s1 >= k per group it IS exact top-k
    if s1 * n_groups >= k and s1 >= min(group, k):
        sv, si = single_stage_topk(scores, k)
        assert float(topk_recall(ti, si).mean()) == 1.0


@given(st.integers(0, 2**31 - 1))
@SETTINGS
def test_two_stage_recall_lower_bounded_by_construction(seed):
    # recall >= k_found/k where each group contributes at most s1
    scores = jax.random.normal(jax.random.PRNGKey(seed), (4, 256))
    tv, ti = two_stage_topk(scores, k=16, group_size=16, stage1_k=2)
    sv, si = single_stage_topk(scores, 16)
    rec = float(topk_recall(ti, si).mean())
    assert rec >= 0.5  # gaussian scores: far above worst case
    # and the selected set's score mass is >= 90% of the true top-k mass
    mass = tv.sum(-1) / sv.sum(-1)
    assert float(mass.min()) > 0.8


@given(st.integers(1, 64), st.sampled_from([6, 7, 8]))
@SETTINGS
def test_adc_monotone(count, bits):
    # ADC readout is monotone in the match count and within 1 count for >=6b
    a = adc_readout(jnp.arange(0, 65, dtype=jnp.float32), cam_w=64, bits=bits)
    assert (jnp.diff(a) >= 0).all()
    assert jnp.abs(a - jnp.arange(0, 65)).max() <= (1.0 if bits == 6 else 0.0)


@given(st.integers(0, 2**31 - 1), st.integers(2, 8))
@SETTINGS
def test_compression_error_feedback_unbiased_over_time(seed, n):
    # repeated compression of a CONSTANT gradient converges to the true
    # mean: error feedback re-injects what quantization dropped
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n, 33)).astype(np.float32))
    errs = jnp.zeros_like(g)
    true_mean = g.mean(0)
    acc = jnp.zeros(33)
    steps = 50
    for _ in range(steps):
        est, errs = compressed_mean_ref(g, errs)
        acc = acc + est
    # telescoping bound: |acc/T - true| <= max_scale/(2T) per pod summed
    drift = jnp.abs(acc / steps - true_mean).max()
    assert float(drift) < 0.02


@given(st.sampled_from([
    # (logical axes, shape) -> must resolve without error, never over-shard
    (("batch", "kv_heads", "kv_seq", "head_dim"), (128, 8, 32768, 128)),
    (("batch", "kv_heads", "kv_seq", "head_dim"), (1, 8, 524288, 128)),
    (("batch", "kv_heads", "kv_seq", "head_dim"), (1, 1, 2048, 256)),
    (("experts", "embed", "expert_mlp"), (48, 1536, 512)),
    (("vocab", "embed"), (152064, 8192)),
]))
@SETTINGS
def test_resolve_spec_divisibility(case):
    from repro.launch.mesh import make_mesh_for
    from repro.sharding.partitioning import CACHE_RULES

    axes, shape = case
    mesh = make_mesh_for(1, 1)
    # trivially valid on a 1x1 mesh
    spec = resolve_spec(axes, shape, mesh, CACHE_RULES)
    assert len(spec) == len(shape)
