import os
import sys

# NOTE: no XLA_FLAGS here on purpose — unit/smoke tests must see the real
# single CPU device.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (test_distributed.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running system/e2e tests (CI fast lane runs -m 'not slow')",
    )
