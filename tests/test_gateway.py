"""Network gateway correctness: the HTTP/SSE stream must be
token-for-token identical to in-process ``engine.stream()``, concurrent
clients must interleave under continuous batching, and a mid-stream
client disconnect must cancel the request and free its pages.

All HTTP here is real sockets against a gateway running on its own
thread + event loop (``serving/gateway.serve_background``); the engine
stays on the gateway's single engine thread throughout."""

import http.client
import json
import socket
import threading
import time

import jax
import pytest

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine
from repro.serving.gateway import request_from_json, serve_background

_SLOW = pytest.mark.slow

_SAMPLING = dict(temperature=0.8, top_k=8, max_new=6)


def _cfg(backend):
    return smoke_config("codeqwen1.5-7b").replace(attn_backend=backend)


def _engine(cfg, **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    return ServeEngine(md, cfg, params, **kw)


def _sse_post(port, spec, timeout=300):
    """POST /v1/generate and collect every SSE event until the final one."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("POST", "/v1/generate", body=json.dumps(spec),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    events, status = [], resp.status
    if status == 200:
        while True:
            line = resp.readline()
            if not line:
                break
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            evt = json.loads(line[6:])
            events.append(evt)
            if evt.get("finished"):
                break
    else:
        events.append(json.loads(resp.read()))
    conn.close()
    return status, events


def _wait_for(cond, timeout=60.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


# ---------------------------------------------------------------------------
# SSE == engine.stream() token-for-token


@pytest.mark.parametrize("backend", [
    "dense",
    pytest.param("camformer", marks=_SLOW),
])
def test_sse_matches_engine_stream(backend):
    cfg = _cfg(backend)
    prompt = [3, 5, 8, 1, 4]
    # reference: plain in-process stream, rid pinned to the rid the
    # gateway runner will assign (0 on a fresh engine) — sampling is
    # keyed by (seed, rid, index), so the tokens must agree exactly
    ref_eng = _engine(cfg)
    want = [out.token for out in ref_eng.stream(
        Request(prompt=list(prompt), rid=0,
                sampling=SamplingParams(**_SAMPLING)))]

    handle = serve_background(_engine(cfg))
    try:
        status, events = _sse_post(handle.port, dict(_SAMPLING, prompt=prompt))
    finally:
        handle.stop()
    assert status == 200
    assert [e["token"] for e in events] == want
    assert [e["index"] for e in events] == list(range(1, len(want) + 1))
    final = events[-1]
    assert final["finished"] and final["finish_reason"] == "length"
    assert final["tokens"] == want  # full-sequence snapshot on the last event


def test_gateway_healthz_metrics_and_validation():
    handle = serve_background(_engine(_cfg("dense")))
    port = handle.port
    try:
        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        conn.request("GET", "/healthz")
        health = json.loads(conn.getresponse().read())
        assert health["status"] == "ok"
        assert health["backend"] == "dense"
        conn.close()

        # malformed bodies are rejected before reaching the engine thread
        for bad in ({"prompt": []}, {"prompt": "hi"}, {"prompt": [1], "max_new": 0},
                    {"prompt": [1], "max_new": 1000}):
            status, events = _sse_post(port, bad)
            assert status == 400, bad
            assert "error" in events[0]

        status, events = _sse_post(
            port, {"prompt": [3, 5, 8], "max_new": 3})
        assert status == 200 and events[-1]["finished"]

        conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
        conn.request("GET", "/metrics")
        metrics = json.loads(conn.getresponse().read())
        conn.close()
        assert metrics["requests"]["completed"] == 1
        assert metrics["requests"]["tokens_out"] == 3
        assert metrics["ttft_ms"]["count"] == 1
        assert metrics["tpot_ms"]["count"] == 2
        assert metrics["engine"]["ticks"] > 0
        assert metrics["engine"]["preemptions"] == 0
        assert metrics["engine"]["pool_pages"] > 0
        # speculative-decode counters are always surfaced (0 with the
        # plain loop; nonzero acceptance books when spec_k > 0)
        assert metrics["engine"]["spec_proposed"] == 0
        assert metrics["engine"]["spec_accepted"] == 0
        assert metrics["engine"]["spec_acceptance"] == 0.0
    finally:
        handle.stop()


def test_request_from_json_validation():
    req = request_from_json(
        {"prompt": [1, 2], "max_new": 4, "temperature": 0.5, "top_k": 3,
         "top_p": 0.9, "stop": [7], "priority": 2}, max_len=32)
    assert req.prompt == [1, 2] and req.priority == 2
    assert req.sampling.stop == (7,) and req.sampling.max_new == 4
    with pytest.raises(ValueError):
        request_from_json({"prompt": [1]}, max_len=16)  # default max_new 32
    with pytest.raises(ValueError):
        request_from_json({"prompt": [True, 2], "max_new": 1})
    with pytest.raises(ValueError):
        request_from_json([1, 2])


# ---------------------------------------------------------------------------
# concurrent clients interleave under continuous batching


def test_concurrent_clients_interleave():
    handle = serve_background(_engine(_cfg("dense")))
    n_clients, results = 3, {}
    barrier = threading.Barrier(n_clients)

    def client(i):
        barrier.wait()
        status, events = _sse_post(
            handle.port,
            {"prompt": [10 + i, 3, 5], "max_new": 10, "temperature": 0.8,
             "top_k": 8})
        results[i] = (status, events)

    try:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(300)
        assert all(not t.is_alive() for t in threads)
        assert all(results[i][0] == 200 for i in range(n_clients))
        assert all(results[i][1][-1]["finished"] for i in range(n_clients))
        rids = {results[i][1][0]["rid"] for i in range(n_clients)}
        assert len(rids) == n_clients

        # the engine-thread routing order: decode ticks emit one token per
        # live request per tick, so concurrently-resident requests must
        # ALTERNATE in the log rather than complete one after another
        log = list(handle.runner.metrics.event_log)
        changes = sum(a[0] != b[0] for a, b in zip(log, log[1:]))
        assert changes > n_clients, (
            f"no continuous-batching interleave in routed order: {log}")
    finally:
        handle.stop()


# ---------------------------------------------------------------------------
# mid-stream disconnect cancels and frees pages


def test_disconnect_cancels_and_frees_pages():
    eng = _engine(_cfg("dense"), max_len=64)
    handle = serve_background(eng)
    try:
        body = json.dumps({"prompt": [3, 5, 8, 1], "max_new": 50,
                           "temperature": 0.8, "top_k": 8}).encode()
        s = socket.create_connection(("127.0.0.1", handle.port), timeout=120)
        s.sendall(b"POST /v1/generate HTTP/1.1\r\n"
                  b"Host: x\r\nContent-Type: application/json\r\n"
                  + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        buf = b""
        while buf.count(b"data: ") < 2:  # two streamed tokens, mid-flight
            chunk = s.recv(4096)
            assert chunk, f"stream ended early: {buf!r}"
            buf += chunk
        first = json.loads(
            buf.split(b"data: ", 1)[1].split(b"\n", 1)[0])
        rid = first["rid"]
        s.close()  # abrupt client disconnect

        assert _wait_for(lambda: any(
            r.rid == rid and r.finish_reason == "cancelled"
            for r in eng.done)), "disconnect did not cancel the request"
        # pages freed immediately: the whole pool is reclaimable again
        # (prefix pages may be LRU-retained; free_pages counts those)
        assert _wait_for(
            lambda: eng.kv.free_pages == eng.kv.n_pages - 1), (
            f"pages leaked after disconnect-cancel: "
            f"{eng.kv.free_pages}/{eng.kv.n_pages - 1}")
        assert _wait_for(lambda: eng.sched._inflight_total == 0)
        assert handle.runner.is_alive()

        # the engine keeps serving after the disconnect
        status, events = _sse_post(
            handle.port, {"prompt": [9, 1, 4], "max_new": 2})
        assert status == 200 and events[-1]["finished"]
    finally:
        handle.stop()
