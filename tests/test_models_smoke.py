"""Per-arch smoke tests: REDUCED same-family configs, one forward/train
step + prefill/decode on CPU, asserting output shapes + no NaNs.
(The FULL configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, smoke_config
from repro.models import get_model_def
from repro.models.module import count_params, init_params

KEY = jax.random.PRNGKey(0)
B, S, CACHE = 2, 32, 48

_IS_LEAF = lambda x: (isinstance(x, tuple) and len(x) == 2
                      and isinstance(x[0], jax.ShapeDtypeStruct))


def make_batch(cfg, b, s, with_labels=True):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": tokens}
    if with_labels:
        batch["labels"] = jnp.roll(tokens, -1, axis=1)
    if cfg.family == "audio":
        batch["audio_features"] = jax.random.normal(
            KEY, (b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.float32)
    return batch


def zero_caches(md, cfg, b, clen):
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        md.cache_specs(cfg, b, clen), is_leaf=_IS_LEAF)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), KEY)
    assert count_params(md.specs(cfg)) > 0
    loss, aux = md.loss(params, make_batch(cfg, B, S), cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # one gradient step moves the loss
    g = jax.grad(lambda p: md.loss(p, make_batch(cfg, B, S), cfg)[0])(params)
    p2 = jax.tree.map(lambda p, g_: p - 0.5 * g_, params, g)
    loss2, _ = md.loss(p2, make_batch(cfg, B, S), cfg)
    assert bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mode", ["dense", "camformer"])
def test_prefill_decode_smoke(arch, mode):
    cfg = smoke_config(arch)
    if mode == "camformer":
        if cfg.family == "ssm":
            pytest.skip("attention-free (DESIGN.md §Arch-applicability)")
        cfg = cfg.replace(attn_backend="camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), KEY)
    caches = zero_caches(md, cfg, B, CACHE)
    logits, caches = md.prefill(params, make_batch(cfg, B, S, False), caches, cfg)
    assert logits.shape[0] == B and logits.shape[1] >= cfg.vocab
    assert bool(jnp.isfinite(logits).all())
    base = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
    pos = jnp.full((B,), base, jnp.int32)
    for _ in range(3):
        logits, caches = md.decode(params, tok, pos, pos + 1, caches, cfg)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
        pos = pos + 1


def test_decode_consistent_with_prefill():
    """Greedy decode continuation must match teacher-forced prefill logits."""
    cfg = smoke_config("codeqwen1.5-7b")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), KEY)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab, jnp.int32)

    # full prefill over 12 tokens
    c1 = zero_caches(md, cfg, 1, CACHE)
    logits_full, _ = md.prefill(params, {"tokens": toks}, c1, cfg)

    # prefill over 11 then decode token 12
    c2 = zero_caches(md, cfg, 1, CACHE)
    _, c2 = md.prefill(params, {"tokens": toks[:, :11]}, c2, cfg)
    logits_step, _ = md.decode(params, toks[:, 11], jnp.array([11]),
                               jnp.array([12]), c2, cfg)
    assert jnp.abs(logits_full - logits_step).max() < 2e-2
