"""Fused paged flash-PREFILL (Sq > 1 chunks) pinned against the
page-gather oracle, kernel-level and through ServeEngine, plus the
flash-prefill hybrid backend and the analytic paged_io_stats pins.

Tolerance policy mirrors test_paged_flash.py: ``prefill_impl="fused"``
vs ``"gather"`` share the page-write path and differ only in the Sq > 1
chunk attend, whose dense/binary arithmetic is a softmax over identical
logits — engine comparisons are TOKEN-FOR-TOKEN exact, kernel
comparisons float-noise allclose.  The hybrid backend's verify chunks
deliberately stay on the CAM path (speculation's exactness contract),
so its fused-vs-gather engine legs cover both chunk kinds.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.configs import smoke_config  # noqa: E402
from repro.core.attention import (AttentionSpec, attention,  # noqa: E402
                                  binary_paged_attention)
from repro.core.backend import get_backend, list_backends  # noqa: E402
from repro.kernels import ops as kops  # noqa: E402
from repro.kernels import ref as kref  # noqa: E402
from repro.models import get_model_def  # noqa: E402
from repro.models.module import init_params  # noqa: E402
from repro.serving import (Request, SamplingParams,  # noqa: E402
                           ServeEngine)

_SLOW = pytest.mark.slow


def _cfg(backend=None, **kw):
    return smoke_config("codeqwen1.5-7b").replace(attn_backend=backend, **kw)


def _pools(key, b=2, hkv=2, d=32, page=8, np_=5, n_pages=12, sq=4):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    k_pages = jax.random.normal(k1, (n_pages, hkv, page, d), jnp.float32)
    v_pages = jax.random.normal(k2, (n_pages, hkv, page, d), jnp.float32)
    pt = jax.random.randint(k3, (b, np_), 1, n_pages).astype(jnp.int32)
    q = jax.random.normal(k4, (b, hkv * 2, sq, d), jnp.float32)
    return q, k_pages, v_pages, pt


def _gather_prefill(q, k_pages, v_pages, pt, kv_len, q_pos, *, window=None,
                    binary=False):
    """Sq>1 oracle: logical-order gather + standard causal attend with
    per-row anchors q_pos + s (row s of the chunk)."""
    sq, d = q.shape[2], q.shape[3]
    if binary:
        # the fused kernel binarizes q/k in-register but keeps the
        # 1/sqrt(d) score scale — fold it into q, attend at scale 1
        q = jnp.where(q > 0, 1.0, -1.0) * (1.0 / (d ** 0.5))
        k_pages = jnp.where(k_pages > 0, 1.0, -1.0)
    ck = kref.paged_gather_ref(k_pages, pt)
    cv = kref.paged_gather_ref(v_pages, pt)
    kv_pos = jnp.arange(ck.shape[2], dtype=jnp.int32)[None]
    q_positions = q_pos.reshape(-1, 1) + jnp.arange(sq, dtype=jnp.int32)
    return attention(
        q, ck, cv, AttentionSpec(mode="dense"), causal=True,
        q_positions=q_positions, kv_positions=kv_pos,
        kv_valid=kv_pos < kv_len.reshape(-1, 1), window=window,
        scale=1.0 if binary else None)


# ---------------------------------------------------------------------------
# kernel level: fused Sq>1 (jnp walk AND Pallas interpreter) == oracle


@pytest.mark.parametrize("binary", [False, True])
@pytest.mark.parametrize("window", [None, 6])
def test_prefill_kernel_matches_gather_oracle(window, binary):
    """Chunk start mid-page (slot 0) and exactly on a page boundary
    (slot 1), intra-chunk causality (row s sees positions <= q_pos+s),
    dead table entries past the extent."""
    sq, page = 4, 8
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(0), page=page, sq=sq)
    # kv_len INCLUDES the chunk; q_pos is the chunk's FIRST position
    kv_len = jnp.array([21, 2 * page + sq], jnp.int32)
    q_pos = kv_len - sq
    want = _gather_prefill(q, k_pages, v_pages, pt, kv_len, q_pos,
                           window=window, binary=binary)
    got = kops.paged_flash_prefill(q, k_pages, v_pages, pt, kv_len, q_pos,
                                   window=window, binary=binary)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_prefill_interpret_matches_walk_and_inert_rows_zero():
    """interpret=True (the Pallas-interpreter CPU hatch) and the off-TPU
    jnp walk share the page sweep and accumulation order; a kv_len == 0
    slot keeps the defined all-zeros inert contract at Sq > 1."""
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(1), sq=4)
    kv_len = jnp.array([13, 0], jnp.int32)
    q_pos = jnp.maximum(kv_len - 4, 0)
    walk = kops.paged_flash_prefill(q, k_pages, v_pages, pt, kv_len, q_pos)
    kern = kops.paged_flash_prefill(q, k_pages, v_pages, pt, kv_len, q_pos,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(walk), atol=1e-6)
    assert jnp.all(kern[1] == 0.0)
    assert jnp.all(walk[1] == 0.0)


def test_prefill_sq1_equals_decode_bitwise():
    """The Sq == 1 chunk degenerates to the decode kernel's exact code
    path — bit-identical, not merely close."""
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(2), sq=1)
    kv_len = jnp.array([16, 7], jnp.int32)
    q_pos = kv_len - 1
    pre = kops.paged_flash_prefill(q, k_pages, v_pages, pt, kv_len, q_pos)
    dec = kops.paged_flash_decode(q, k_pages, v_pages, pt, kv_len, q_pos)
    assert jnp.array_equal(pre, dec)


def test_binary_paged_attention_sq_gt1_impls_agree():
    """binary_paged_attention's Sq>1 fused branch (paged_flash_prefill,
    in-register K binarization + folded per-slot temperature) == its
    gather impl."""
    sq = 3
    q, k_pages, v_pages, pt = _pools(jax.random.PRNGKey(3), sq=sq)
    b, hkv = pt.shape[0], k_pages.shape[1]
    kv_len = jnp.array([19, sq], jnp.int32)
    q_pos = (kv_len - sq).reshape(b, 1) + jnp.arange(sq)[None]
    k_scale = jax.random.uniform(jax.random.PRNGKey(4), (b, hkv)) + 0.5
    outs = {
        impl: binary_paged_attention(
            q, k_pages, v_pages, k_scale, pt, kv_len, q_pos, impl=impl)
        for impl in ("fused", "gather")
    }
    np.testing.assert_allclose(np.asarray(outs["fused"]),
                               np.asarray(outs["gather"]), atol=1e-5)


# ---------------------------------------------------------------------------
# engine level: prefill_impl fused == gather token-for-token


def _run_engine(cfg, prefill_impl, prompts, *, max_new=5, spec_k=None,
                mode="sync", **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    eng = ServeEngine(md, cfg, params, mode=mode, prefill_slice=8,
                      prefill_impl=prefill_impl, spec_k=spec_k, **kw)
    sampling = SamplingParams(temperature=0.8, top_k=12, max_new=max_new)
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p), sampling=sampling, rid=i))
    done = {r.rid: r.tokens for r in eng.run()}
    assert eng.kv.free_pages == eng.kv.n_pages - 1  # drained clean
    return done


_SHARED = list(range(30, 42))  # 12 tokens: COW fork mid-page (page 8)
_PROMPTS = [_SHARED + [i, i + 2] for i in (3, 7)] + [[9, 1, 4], [2, 2]]


@pytest.mark.parametrize("backend", ["dense", "binary", "hybrid"])
def test_engine_chunked_prefill_fused_matches_gather(backend):
    """Chunked prefill (prefill_slice=8) with a COW boundary-page fork
    and keyed sampling: the Sq>1 fused flash chunks must reproduce the
    gather oracle token-for-token through the full engine."""
    cfg = _cfg(backend)
    got = {impl: _run_engine(cfg, impl, _PROMPTS)
           for impl in ("fused", "gather")}
    assert got["fused"] == got["gather"]
    assert set(got["fused"]) == set(range(len(_PROMPTS)))


@pytest.mark.parametrize("backend", [
    "binary", pytest.param("hybrid", marks=_SLOW)])
def test_engine_spec_verify_fused_matches_gather(backend):
    """Speculative verify chunks (Sq = k+1) under each prefill_impl:
    exact k_scale sequencing / k_means repair must keep the accepted
    token streams identical.  hybrid's verify chunks take the CAM path
    regardless of impl (exactness contract), so this also pins that
    routing."""
    cfg = _cfg(backend)
    got = {impl: _run_engine(cfg, impl, _PROMPTS, spec_k=3)
           for impl in ("fused", "gather")}
    assert got["fused"] == got["gather"]


@_SLOW
def test_engine_overlap_mixed_stack_fused_matches_gather():
    """A mixed ("dense", "camformer") stack in the overlapped loop:
    dense layers flip chunk realizations, camformer layers stay on
    gather chunks under either impl (no fused Sq>1 CAM kernel)."""
    cfg = smoke_config("codeqwen1.5-7b").replace(
        attn_backend=None, layer_backends=("dense", "camformer"))
    got = {impl: _run_engine(cfg, impl, _PROMPTS[:3], mode="overlap")
           for impl in ("fused", "gather")}
    assert got["fused"] == got["gather"]


# ---------------------------------------------------------------------------
# hybrid backend: registry, layout, serving smoke


def test_hybrid_registered_with_dual_key_layout():
    assert "hybrid" in list_backends()
    bk = get_backend("hybrid")
    assert bk.mode == "camformer"  # CAM decode path
    cfg = _cfg("hybrid")
    spec = bk.page_spec(cfg, n_pages=6, page_size=8, max_batch=2,
                        dtype=jnp.float32)
    # both key representations + the CAM temperature state
    for name in ("k_pages", "kp_pages", "v_pages", "k_scale"):
        assert name in spec, name
    sds, axes = spec["k_pages"]
    assert sds.shape == (6, cfg.n_kv_heads, 8, cfg.head_dim)
    assert axes == (None, "kv_heads", None, "head_dim")  # tp-shardable
    # bytes/token: packed keys + dense keys + dense values
    d, item = cfg.head_dim, 4
    assert (bk.cache_bytes_per_token(cfg, jnp.float32)
            == cfg.n_kv_heads * (d // 8 + 2 * d * item))


def test_hybrid_write_keeps_both_pools_current():
    """One _paged_write must land the same rows in the dense k_pages
    (flash prefill) and the packed kp_pages (CAM decode)."""
    cfg = _cfg("hybrid")
    bk = get_backend("hybrid")
    b, page, hkv, d = 1, 8, cfg.n_kv_heads, cfg.head_dim
    spec = bk.page_spec(cfg, 4, page, b, jnp.float32)
    pools = {n: jnp.zeros(sds.shape, sds.dtype)
             for n, (sds, _) in spec.items()}
    s = 4
    k = jax.random.normal(jax.random.PRNGKey(7), (b, hkv, s, d))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, hkv, s, d))
    pos = jnp.arange(s, dtype=jnp.int32)[None]
    pt = jnp.array([[2, 3]], jnp.int32)
    new = bk._paged_write(pools, k, v, pos, pt, jnp.array([s], jnp.int32),
                          cfg)
    # dense rows: exact K values at page 2, rows 0..s
    np.testing.assert_allclose(np.asarray(new["k_pages"][2, :, :s]),
                               np.asarray(k[0]), atol=1e-6)
    # packed rows: the sign bits of the same K
    from repro.core.bacam import unpack_bits

    unpacked = unpack_bits(new["kp_pages"][2, :, :s], d)
    assert jnp.array_equal(unpacked > 0, k[0] > 0)


# ---------------------------------------------------------------------------
# paged_io_stats: analytic fused/gather byte pins (satellite 3)


@pytest.mark.parametrize("backend", ["dense", "binary", "camformer",
                                     "hybrid"])
def test_paged_io_stats_pinned_against_pool_layout(backend):
    """The analytic decode/prefill read-byte columns, re-derived from
    the backend's OWN page_spec layout (deterministic measured bytes:
    pool row nbytes x rows touched) — the bench harness divides these
    by chunk size for its per-prefill-token artifact numbers."""
    cfg = _cfg(backend)
    bk = get_backend(backend)
    kv_len, page, n_table = 21, 8, 4
    dtype = jnp.float32
    io = bk.paged_io_stats(cfg, dtype, kv_len=kv_len, page_size=page,
                           n_table_pages=n_table)
    spec = bk.page_spec(cfg, 1, page, 1, dtype)  # layout probe: 1 page
    tok = {n: sds.size * jnp.dtype(sds.dtype).itemsize // page
           for n, (sds, _) in spec.items() if n.endswith("_pages")}
    live_rows = -(-kv_len // page) * page
    table_rows = n_table * page
    if backend in ("dense", "binary"):
        # binary pools store dense float K (binarized in-register at
        # attend time), so its accounting is the base dense one
        row = tok["k_pages"] + tok["v_pages"]
        assert io["fused_read_bytes"] == live_rows * row
        assert io["gather_read_bytes"] == table_rows * row
        assert io["prefill_fused_read_bytes"] == live_rows * row
        assert io["prefill_gather_read_bytes"] == table_rows * row
    else:
        # CAM decode: packed-key sweep + top-k value selection
        g = cfg.n_heads // cfg.n_kv_heads
        v_sel = (cfg.n_kv_heads * g * min(cfg.k_top, kv_len)
                 * cfg.head_dim * jnp.dtype(dtype).itemsize)
        assert io["fused_read_bytes"] == live_rows * tok["kp_pages"] + v_sel
        assert (io["gather_read_bytes"]
                == table_rows * tok["kp_pages"] + v_sel)
        dense_row = 2 * cfg.n_kv_heads * cfg.head_dim * 4
        if backend == "hybrid":
            # prefill chunks flash-read the dense pools
            assert tok["k_pages"] == dense_row // 2
            assert io["prefill_fused_read_bytes"] == live_rows * dense_row
            assert (io["prefill_gather_read_bytes"]
                    == table_rows * dense_row)
        else:
            # no fused Sq>1 CAM kernel yet: both prefill columns are
            # the gather numbers (the bench <= gate holds trivially)
            assert (io["prefill_fused_read_bytes"]
                    == io["prefill_gather_read_bytes"]
                    == table_rows * tok["kp_pages"] + v_sel)
    assert io["prefill_fused_read_bytes"] <= io["prefill_gather_read_bytes"]


def test_paged_io_stats_matches_bench_artifact_column():
    """The bench harness's kv_read_bytes_per_prefill_token column is
    exactly io[prefill_<impl>_read_bytes] * n_layers / chunk — pin the
    wiring so artifact numbers stay interpretable."""
    from benchmarks.paged_decode import bench_prefill_impl

    row = bench_prefill_impl("dense", max_batch=2, repeats=1)
    cfg = _cfg("dense")
    from repro.models.transformer import dtype_of

    io = get_backend("dense").paged_io_stats(
        cfg, dtype_of(cfg), kv_len=row["prompt_len"],
        page_size=row["prefill_slice"],
        n_table_pages=96 // row["prefill_slice"])
    for impl in ("fused", "gather"):
        want = (io[f"prefill_{impl}_read_bytes"] * cfg.n_layers
                / row["prefill_slice"])
        assert (row["lanes"][impl]["kv_read_bytes_per_prefill_token"]
                == want), impl
    assert row["fused_vs_gather_chunk_ticks"] > 0


# ---------------------------------------------------------------------------
# serving counters + gateway metrics (satellite 2)


def test_engine_prefill_counters_track_chunks():
    """prefill_tokens / prefill_ticks (the TTFT attribution pair): a
    24-token prompt at prefill_slice=8 is exactly 3 chunk ticks and 24
    prefill tokens; decode ticks leave both untouched."""
    cfg = _cfg("dense")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8,
                      mode="sync", prefill_slice=8)
    assert (eng.prefill_tokens, eng.prefill_ticks) == (0, 0)
    eng.submit(Request(prompt=list(range(50, 74)),
                       sampling=SamplingParams(max_new=4), rid=0))
    eng.run()
    assert eng.prefill_tokens == 24
    assert eng.prefill_ticks == 3
    ticks_after = eng.prefill_ticks
    eng.submit(Request(prompt=[1, 2, 3],
                       sampling=SamplingParams(max_new=2), rid=1))
    eng.run()
    assert eng.prefill_tokens == 27  # short prompt: one 3-token chunk
    assert eng.prefill_ticks == ticks_after + 1


def test_gateway_metrics_exposes_prefill_counters():
    """GET /metrics carries the engine's prefill attribution next to the
    spec/preemption counters (no HTTP server needed: the handler's
    metrics dict is built by Gateway._metrics)."""
    from repro.serving.gateway import Gateway

    cfg = _cfg("dense")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64, page_size=8,
                      mode="sync", prefill_slice=8)
    eng.submit(Request(prompt=list(range(40, 56)),
                       sampling=SamplingParams(max_new=2), rid=0))
    eng.run()
    gw = Gateway(eng)
    m = gw._metrics()
    assert m["engine"]["prefill_tokens"] == 16
    assert m["engine"]["prefill_ticks"] == 2
    assert "spec_proposed" in m["engine"]  # sits next to the spec stats
    assert "preemptions" in m["engine"]
