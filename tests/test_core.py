"""Core CAMformer algorithm tests: BA-CAM device model, two-stage top-k,
attention modes, HAD distillation, energy model reproduction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AttentionSpec, NEG_INF, attention, bacam_scores,
                        binary_scores_exact, dense_reference,
                        hamming_scores_packed, hoeffding_drop_bound,
                        pack_bits, sign_pm1, sign_ste, single_stage_topk,
                        topk_recall, two_stage_topk, unpack_bits)
from repro.core.energy import (attention_query_cost, energy_vs_m,
                               PUBLISHED_CAMFORMER, PUBLISHED_CAMFORMER_MHA,
                               table2_rows)
from repro.core.had import attention_kl, row_topk_overlap

KEY = jax.random.PRNGKey(0)


# ---------------- BA-CAM device model ----------------

@pytest.mark.parametrize("d", [32, 64, 128, 256])
def test_pack_unpack_roundtrip(d):
    x = sign_pm1(jax.random.normal(KEY, (3, 7, d)))
    assert (unpack_bits(pack_bits(x), d) == x).all()


@pytest.mark.parametrize("d", [64, 128, 256])
def test_packed_hamming_equals_pm1_matmul(d):
    qb = sign_pm1(jax.random.normal(KEY, (2, 5, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(1), (2, 9, d)))
    s_packed = hamming_scores_packed(pack_bits(qb), pack_bits(kb), d)
    s_exact = binary_scores_exact(qb, kb)
    assert (s_packed == s_exact).all()
    assert s_packed.min() >= -d and s_packed.max() <= d


def test_adc_seven_bits_exact_six_bits_sub_lsb():
    d = 64
    qb = sign_pm1(jax.random.normal(KEY, (4, 16, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(2), (4, 32, d)))
    exact = binary_scores_exact(qb, kb).astype(jnp.float32)
    adc7 = bacam_scores(qb, kb, exact=False, adc_bits=7)
    adc6 = bacam_scores(qb, kb, exact=False, adc_bits=6)
    assert (adc7 == exact).all()  # 7-bit ADC covers [0,64] exactly
    assert jnp.abs(adc6 - exact).max() <= 4  # paper's 6-bit: sub-LSB/count
    # either way score ORDERING is nearly preserved (paper's claim)
    def order_err(a, b):
        ia = jnp.argsort(a, axis=-1)
        ib = jnp.argsort(b, axis=-1)
        return (ia != ib).mean()
    assert order_err(adc6, exact) < 0.5  # ties may permute, gross order holds


def test_matchline_noise_sigma_matches_paper():
    # sigma = 1.4% of full scale => mean |error| ~ sigma*2*cam_w per tile
    d = 64
    qb = sign_pm1(jax.random.normal(KEY, (8, 32, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(3), (8, 32, d)))
    exact = binary_scores_exact(qb, kb).astype(jnp.float32)
    noisy = bacam_scores(qb, kb, exact=False, noise_sigma=0.014,
                         rng=jax.random.PRNGKey(9))
    rel = jnp.abs(noisy - exact).mean() / (2 * d)
    assert 0.002 < rel < 0.03  # ~1.4% w/ gaussian folding


def test_vertical_tiling_matches_flat():
    # d=256 -> 4 CAM tiles accumulated digitally == flat dot product
    d = 256
    qb = sign_pm1(jax.random.normal(KEY, (2, 6, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(4), (2, 6, d)))
    tiled = bacam_scores(qb, kb, exact=False, adc_bits=7)
    assert (tiled == binary_scores_exact(qb, kb)).all()


def test_sign_ste_gradient():
    g = jax.grad(lambda x: (sign_ste(x) * jnp.arange(1.0, 4.0)).sum())(
        jnp.array([0.5, -2.0, 0.1]))
    assert g[0] == 1.0 and g[1] == 0.0 and g[2] == 3.0  # clipped STE


# ---------------- two-stage top-k ----------------

def test_two_stage_equals_single_stage_when_spread():
    # if every group holds <= stage1_k of the true top-k, recall == 1
    rng = np.random.default_rng(0)
    n, k, g = 512, 16, 16
    scores = rng.normal(size=(4, n)).astype(np.float32)
    # place the top-k one per group
    for b in range(4):
        top_groups = rng.choice(n // g, size=k, replace=False)
        for j, grp in enumerate(top_groups):
            scores[b, grp * g + rng.integers(g)] = 100.0 + j
    tv, ti = two_stage_topk(jnp.asarray(scores), k=k, group_size=g, stage1_k=2)
    sv, si = single_stage_topk(jnp.asarray(scores), k)
    assert float(topk_recall(ti, si).mean()) == 1.0
    assert jnp.allclose(jnp.sort(tv), jnp.sort(sv))


def test_two_stage_drops_group_overflow():
    # all top scores in ONE group with stage1_k=2 -> only 2 survive
    scores = np.zeros((1, 64), np.float32)
    scores[0, :8] = np.arange(8, 0, -1) + 100  # 8 best all in group 0
    tv, ti = two_stage_topk(jnp.asarray(scores), k=4, group_size=16, stage1_k=2)
    assert set(np.asarray(ti)[0, :2].tolist()) == {0, 1}
    assert (np.asarray(tv)[0, 2:] < 100).all()  # rest come from other groups


def test_two_stage_masking():
    scores = jnp.ones((2, 64))
    where = jnp.zeros((2, 64), bool).at[:, 5].set(True)
    tv, ti = two_stage_topk(scores, k=4, group_size=16, stage1_k=2, where=where)
    assert (ti[:, 0] == 5).all()
    assert (tv[:, 1:] <= NEG_INF / 2).all()


def test_hoeffding_bound_monotone():
    # unclamped region: larger margin / more matches => smaller drop prob
    assert hoeffding_drop_bound(256, 0.25, 32, 1024) < hoeffding_drop_bound(
        256, 0.15, 32, 1024)
    assert hoeffding_drop_bound(512, 0.15, 32, 1024) < hoeffding_drop_bound(
        256, 0.15, 32, 1024)
    assert hoeffding_drop_bound(64, 0.0, 32, 1024) == 1.0  # clamps at 1
    assert hoeffding_drop_bound(256, 0.25, 32, 1024) < 1e-3


# ---------------- attention modes ----------------

def test_camformer_attention_matches_binary_at_full_k():
    # top-k == Skv => camformer == binary (same softmax over all keys)
    q = jax.random.normal(KEY, (2, 4, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 16, 64))
    a = attention(q, k, v, AttentionSpec(mode="binary"), causal=False)
    b = attention(q, k, v, AttentionSpec(mode="camformer", k_top=16,
                                         group_size=16, stage1_k=16),
                  causal=False)
    assert jnp.allclose(a, b, atol=1e-5)


def test_camformer_attention_approximates_dense():
    # correlated q/k: binary top-32 output should correlate with dense
    base = jax.random.normal(KEY, (1, 2, 32, 64))
    q = base + 0.1 * jax.random.normal(jax.random.PRNGKey(1), base.shape)
    k = base + 0.1 * jax.random.normal(jax.random.PRNGKey(2), base.shape)
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 32, 64))
    d = dense_reference(q, k, v, causal=True)
    c = attention(q, k, v, AttentionSpec(mode="camformer", k_top=8), causal=True)
    cos = jnp.sum(d * c) / (jnp.linalg.norm(d) * jnp.linalg.norm(c))
    assert cos > 0.7


def test_gqa_matches_repeated_kv():
    q = jax.random.normal(KEY, (2, 8, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 8, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 8, 64))
    out = dense_reference(q, k, v, causal=True)
    out_rep = dense_reference(q, jnp.repeat(k, 4, 1), jnp.repeat(v, 4, 1),
                              causal=True)
    assert jnp.allclose(out, out_rep, atol=1e-5)


def test_window_masking():
    q = jax.random.normal(KEY, (1, 2, 16, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 16, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 16, 32))
    full = dense_reference(q, k, v, causal=True)
    win = dense_reference(q, k, v, causal=True, window=4)
    # first positions (inside window) identical; later differ
    assert jnp.allclose(full[:, :, :4], win[:, :, :4], atol=1e-5)
    assert not jnp.allclose(full[:, :, -1], win[:, :, -1], atol=1e-3)


def test_trainable_camformer_grads():
    q = jax.random.normal(KEY, (1, 2, 8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 16, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 16, 64))
    spec = AttentionSpec(mode="camformer", k_top=4, trainable_binarize=True)

    def loss(q, k, v):
        return (attention(q, k, v, spec, causal=False) ** 2).sum()

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(gv).sum()) > 0


# ---------------- HAD ----------------

def test_attention_kl_zero_at_identity():
    logits = jax.random.normal(KEY, (2, 4, 8, 8))
    assert float(attention_kl(logits, logits)) < 1e-6
    other = logits + jax.random.normal(jax.random.PRNGKey(1), logits.shape)
    assert float(attention_kl(logits, other)) > 0.01


def test_row_topk_overlap_bounds():
    a = jax.random.normal(KEY, (2, 8, 64))
    assert float(row_topk_overlap(a, a, k=8)) == 1.0


# ---------------- energy / system simulator (Table II, Figs 5/8/9) ------

def test_table2_reproduces_published_camformer_row():
    rows = table2_rows()
    ours = rows["CAMformer (ours, simulated)"]
    assert abs(ours["thr_qry_ms"] - PUBLISHED_CAMFORMER["thr_qry_ms"]) / \
        PUBLISHED_CAMFORMER["thr_qry_ms"] < 0.02
    assert abs(ours["eff_qry_mj"] - PUBLISHED_CAMFORMER["eff_qry_mj"]) / \
        PUBLISHED_CAMFORMER["eff_qry_mj"] < 0.02
    assert abs(ours["area_mm2"] - PUBLISHED_CAMFORMER["area_mm2"]) < 0.01
    assert abs(ours["power_w"] - PUBLISHED_CAMFORMER["power_w"]) < 0.02
    mha = rows["CAMformer_MHA (ours, simulated)"]
    assert abs(mha["thr_qry_ms"] - PUBLISHED_CAMFORMER_MHA["thr_qry_ms"]) / \
        PUBLISHED_CAMFORMER_MHA["thr_qry_ms"] < 0.02


def test_energy_breakdown_matches_fig8():
    c = attention_query_cost()
    s = c["energy_shares"]
    assert abs(s["v_sram"] - 0.31) < 0.03
    assert abs(s["k_sram"] - 0.20) < 0.03
    assert abs(s["mac"] - 0.26) < 0.03
    assert abs(s["bacam"] - 0.12) < 0.03


def test_stage_throughput_contextualization_is_bottleneck():
    # Fig. 9: 8 MACs balance ctx against assoc; ctx is the longest stage
    c = attention_query_cost()
    sc = c["stage_cycles"]
    assert sc["contextualization"] >= sc["association"]
    assert sc["contextualization"] >= sc["normalization"]


def test_energy_vs_m_amortization():
    e = energy_vs_m((1, 16, 256))
    assert e[1] > e[16] > e[256]  # Fig. 5: programming cost amortizes
