"""HLO analyzer: loop-aware FLOP/collective accounting (the roofline's
foundation — cost_analysis() counts while bodies once; we must not)."""

import jax
import jax.numpy as jnp

from repro.utils.hlo import analyze_hlo


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y)

    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = jax.jit(f).lower(sds, sds).compile()
    r = analyze_hlo(comp.as_text())
    assert abs(r["flops"] - 10 * 2 * 128**3) / (10 * 2 * 128**3) < 0.01
    assert any(abs(v - 10.0) < 0.5 for v in r["loop_multipliers"].values())


def test_nested_scan_multipliers_compose():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return jnp.sum(y)

    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp = jax.jit(f).lower(sds, sds).compile()
    r = analyze_hlo(comp.as_text())
    want = 12 * 2 * 64**3  # 4 x 3 iterations
    assert abs(r["flops"] - want) / want < 0.01


def test_no_loop_program_counts_once():
    def f(a, b):
        return (a @ b).sum()

    sds = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    sds2 = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    comp = jax.jit(f).lower(sds, sds2).compile()
    r = analyze_hlo(comp.as_text())
    want = 2 * 64 * 32 * 16
    assert abs(r["flops"] - want) / want < 0.01
    assert r["collective_bytes"] == 0
