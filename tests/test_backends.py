"""AttentionBackend API: registry semantics, config-level backend
resolution (incl. the removed attn_mode alias erroring), and the per-layer
backend policy — mixed dense/camformer stacks must round-trip cache
specs, prefill, decode, and serve end-to-end through the single paged
ServeEngine with both page layouts live in the same pool."""

import argparse
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.configs import smoke_config
from repro.core.backend import (AttentionBackend, get_backend, list_backends,
                                register_backend)
from repro.launch.cli import add_backend_args, apply_backend_args
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, SamplingParams, ServeEngine

_IS_LEAF = lambda x: (isinstance(x, tuple) and len(x) == 2
                      and isinstance(x[0], jax.ShapeDtypeStruct))

MIXED = ("dense", "camformer", "dense", "camformer")


def _zeros(specs):
    return jax.tree.map(lambda t: jnp.zeros(t[0].shape, t[0].dtype),
                        specs, is_leaf=_IS_LEAF)


def _mixed_cfg(**kw):
    cfg = smoke_config("codeqwen1.5-7b")
    assert cfg.n_layers == 2  # smoke depth; cycle covers all 4 entries
    return cfg.replace(n_layers=4, layer_backends=MIXED, **kw)


# ---------------------------------------------------------------------------
# registry + config resolution


def test_registry_round_trip():
    assert {"dense", "binary", "camformer"} <= set(list_backends())
    for name in ("dense", "binary", "camformer"):
        bk = get_backend(name)
        assert bk.name == name
        assert get_backend(name) is bk  # singletons
    with pytest.raises(KeyError):
        get_backend("analog-tbd")

    class _Probe(AttentionBackend):
        name = "probe"
        mode = "dense"

    register_backend(_Probe())
    assert get_backend("probe").name == "probe"


def test_attn_mode_alias_removed_is_clean_error():
    """The seed-era attn_mode spelling (deprecated in PR 2-3) is removed:
    stale replace(attn_mode=...) call sites fail at config construction
    with a message pointing at attn_backend, never a silent no-op or an
    opaque TypeError.  The canonical spelling stays warning-free."""
    cfg = smoke_config("codeqwen1.5-7b")
    with pytest.raises(ValueError, match="attn_mode.*removed"):
        cfg.replace(attn_mode="camformer")
    with pytest.raises(ValueError, match="attn_backend"):
        cfg.replace(attn_mode="binary", attn_backend="camformer")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert cfg.replace(attn_backend="binary").backend == "binary"


def test_cli_attn_mode_flag_removed_is_clean_error():
    ap = argparse.ArgumentParser()
    add_backend_args(ap)
    args = ap.parse_args(["--attn-mode", "camformer"])
    with pytest.raises(SystemExit, match="removed.*--backend camformer"):
        apply_backend_args(smoke_config("codeqwen1.5-7b"), args)
    # the canonical flag still routes
    args = ap.parse_args(["--backend", "camformer"])
    assert apply_backend_args(
        smoke_config("codeqwen1.5-7b"), args).backend == "camformer"


def test_config_backend_resolution():
    cfg = smoke_config("codeqwen1.5-7b")
    assert cfg.backend == "dense"
    assert cfg.replace(attn_backend="camformer").backend == "camformer"
    # typed per-layer accessor: uniform...
    assert cfg.backend_for(1) == "dense"
    assert cfg.uniform_backend == "dense"
    # ...and per-layer policy, cycled over the stack like layer_pattern
    mixed = cfg.replace(n_layers=4, layer_backends=("dense", "camformer"))
    assert mixed.backend_names == ("dense", "camformer", "dense", "camformer")
    assert mixed.backend_for(3) == "camformer"
    assert mixed.uniform_backend is None
    # a mixed policy has no single default backend: consumers that cannot
    # thread backend_for(layer) must fail loudly, never silently default
    with pytest.raises(ValueError, match="mixed layer_backends"):
        mixed.backend
    # ...but a uniform layer_backends tuple still resolves
    assert cfg.replace(layer_backends=("camformer",)).backend == "camformer"
    with pytest.raises(ValueError):
        cfg.replace(layer_backends=())


# ---------------------------------------------------------------------------
# per-layer policy: spec round-trip


def test_mixed_layer_cache_and_page_specs_round_trip():
    cfg = _mixed_cfg()
    md = get_model_def(cfg)
    caches = md.cache_specs(cfg, 2, 32)
    pages = md.page_specs(cfg, 9, 8, 2)
    assert isinstance(caches, tuple) and len(caches) == cfg.n_layers
    assert isinstance(pages, tuple) and len(pages) == cfg.n_layers
    for i, name in enumerate(MIXED):
        want_cache = {"dense": {"k", "v"},
                      "camformer": {"k_packed", "v", "k_scale"}}[name]
        want_page = {"dense": {"k_pages", "v_pages"},
                     "camformer": {"kp_pages", "v_pages", "k_scale"}}[name]
        assert set(caches[i]) == want_cache, i
        assert set(pages[i]) == want_page, i
        # spec trees match what the layer's backend declares directly
        bk = get_backend(cfg.backend_for(i))
        direct = bk.page_spec(cfg, 9, 8, 2, jnp.dtype(cfg.dtype))
        assert {k: v[0].shape for k, v in pages[i].items()} == {
            k: v[0].shape for k, v in direct.items()}


# ---------------------------------------------------------------------------
# per-layer policy: prefill / decode consistency (contiguous caches)


def test_mixed_layer_decode_consistent_with_prefill():
    """Mixed stacks unroll with per-layer cache trees; stepping the last
    prompt token must reproduce the one-shot prefill logits."""
    cfg = _mixed_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab,
                              jnp.int32)
    c1 = _zeros(md.cache_specs(cfg, 1, 32))
    full, _ = md.prefill(params, {"tokens": toks}, c1, cfg)
    c2 = _zeros(md.cache_specs(cfg, 1, 32))
    _, c2 = md.prefill(params, {"tokens": toks[:, :11]}, c2, cfg)
    stepped, _ = md.decode(params, toks[:, 11], jnp.array([11]),
                           jnp.array([12]), c2, cfg)
    # the CAM layers' prefill-vs-decode discrepancy (binarization tie
    # flips, tolerated at 2e-2 per 2-layer stack by the seed tests)
    # compounds with depth: 4 layers / 2 CAM layers sits just above 2e-2
    assert float(jnp.abs(full - stepped).max()) < 5e-2


def test_mixed_layer_close_to_all_dense():
    """The CAM layers only top-k-truncate + binarize their half of the
    stack: mixed-policy prefill logits stay directionally aligned with the
    all-dense oracle (deterministic seed; tolerance covers the top-k
    truncation on the CAM layers)."""
    cfg = _mixed_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab,
                              jnp.int32)
    lm, _ = md.prefill(params, {"tokens": toks},
                       _zeros(md.cache_specs(cfg, 2, 32)), cfg)
    dense = cfg.replace(layer_backends=None)  # all-dense oracle
    ld, _ = md.prefill(params, {"tokens": toks},
                       _zeros(md.cache_specs(dense, 2, 32)), dense)
    cos = float(jnp.sum(lm * ld)
                / (jnp.linalg.norm(lm) * jnp.linalg.norm(ld) + 1e-9))
    assert cos > 0.9, cos


def test_mixed_layer_train_step_smoke():
    cfg = _mixed_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab,
                              jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    loss, _ = md.loss(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: md.loss(p, batch, cfg)[0])(params)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# per-layer policy: end-to-end paged serving, both layouts in one pool


def test_mixed_layer_engine_serves_with_both_page_layouts():
    """A mixed layer_backends config serves end-to-end through the single
    paged ServeEngine: dense bf16 pages and camformer bit-packed pages
    live side by side in the same pool, and the engine's greedy output
    matches the contiguous-cache mixed reference token-for-token."""
    cfg = _mixed_cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    prompts = [[5, 9, 2], [7, 7, 1, 3, 8], [11, 4]]
    new = 5

    def reference(p):
        dc = _zeros(md.cache_specs(cfg, 1, 32))
        logits, dc = md.prefill(
            params, {"tokens": jnp.asarray(p, jnp.int32)[None]}, dc, cfg)
        toks = [int(jnp.argmax(logits[0]))]
        pos = len(p)
        for _ in range(new - 1):
            logits, dc = md.decode(
                params, jnp.asarray([toks[-1]], jnp.int32),
                jnp.asarray([pos], jnp.int32),
                jnp.asarray([pos + 1], jnp.int32), dc, cfg)
            toks.append(int(jnp.argmax(logits[0])))
            pos += 1
        return toks

    want = {i: reference(p) for i, p in enumerate(prompts)}

    eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32, page_size=8)
    # both layouts live in the same pool
    assert isinstance(eng.caches, tuple) and len(eng.caches) == 4
    assert set(eng.caches[0]) == {"k_pages", "v_pages"}
    assert set(eng.caches[1]) == {"kp_pages", "v_pages", "k_scale"}
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p), sampling=SamplingParams(max_new=new), rid=i))
    done = eng.run()
    got = {r.rid: r.tokens for r in done}
    assert got == want
    assert eng.kv.free_pages == eng.kv.n_pages - 1


def test_engine_requires_paged_interface():
    cfg = smoke_config("rwkv6-3b")  # attention-free: no paged interface
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged serving interface"):
        ServeEngine(md, cfg, params, max_batch=2, max_len=32)
