"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import pack_bits, sign_pm1
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("b,r,skv,d", [
    (1, 8, 16, 64), (2, 37, 100, 64), (1, 129, 257, 128),
    (3, 8, 40, 256), (1, 300, 64, 96),
])
def test_bacam_mvm_matches_oracle(b, r, skv, d):
    qb = sign_pm1(jax.random.normal(KEY, (b, r, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(1), (b, skv, d)))
    got = ops.bacam_scores(qb, kb)
    want = ref.bacam_scores_ref(pack_bits(qb), pack_bits(kb), d)
    assert (got == want).all()


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8), (False, None)])
@pytest.mark.parametrize("group,s1", [(16, 2), (8, 1), (4, 4)])
def test_bacam_topk_stage1_matches_oracle(causal, window, group, s1):
    b, r, skv, d = 2, 24, 96, 64
    qb = sign_pm1(jax.random.normal(KEY, (b, r, d)))
    kb = sign_pm1(jax.random.normal(jax.random.PRNGKey(2), (b, skv, d)))
    qpos = jnp.tile(jnp.arange(r, dtype=jnp.int32)[None] * 4, (b, 1))
    kvlen = jnp.array([skv, skv - 30], jnp.int32)
    gv, gi = ops.bacam_attention_scores_topk(
        qb, kb, qpos, kvlen, group=group, stage1_k=s1, causal=causal,
        window=window)
    rv, ri = ref.bacam_topk_stage1_ref(
        pack_bits(qb), pack_bits(kb), d, qpos, group_size=group, stage1_k=s1,
        causal=causal, window=window, kv_len=kvlen)
    rvf = jnp.where(rv <= ref.MASKED_SCORE // 2, -1e9, rv.astype(jnp.float32))
    assert (gv == rvf).all()
    # indices must agree wherever valid (ties can permute equal VALUES, so
    # compare the scores addressed by the indices instead of raw indices)
    s_full = ref.bacam_scores_ref(pack_bits(qb), pack_bits(kb), d)
    s_full = ref.masked_scores_ref(s_full, qpos, causal=causal, window=window,
                                   kv_len=kvlen)
    valid = gv > -1e8
    picked = jnp.take_along_axis(s_full, gi, axis=-1)
    assert (jnp.where(valid, picked, 0) == jnp.where(valid, gv.astype(jnp.int32), 0)).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,d,causal,off,win", [
    (2, 64, 64, 64, True, 0, None),
    (1, 128, 128, 32, True, 0, 48),
    (2, 16, 128, 64, True, 112, None),
    (1, 64, 128, 64, False, 0, None),
])
def test_flash_attention_matches_oracle(dtype, b, sq, skv, d, causal, off, win):
    q = jax.random.normal(KEY, (b, sq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, d), jnp.float32).astype(dtype)
    got = ops.flash_attention(q, k, v, off, causal=causal, window=win,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, q_offset=off,
                                   window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.abs(got.astype(jnp.float32) - want.astype(jnp.float32)).max() < tol


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("b,r,n,d", [(1, 8, 16, 64), (2, 17, 33, 64), (1, 64, 40, 128)])
def test_bitslice_vmm_exact(bits, b, r, n, d):
    x = sign_pm1(jax.random.normal(KEY, (b, r, d)))
    w = jax.random.randint(jax.random.PRNGKey(3), (b, n, d),
                           -(2 ** (bits - 1)), 2 ** (bits - 1), jnp.int32)
    got = ops.bitslice_vmm(x, w, bits=bits)
    want = ref.bitslice_vmm_ref(x, w, bits)
    assert (got == want).all()


def test_kernel_attention_equals_jnp_attention():
    from repro.core import AttentionSpec, attention

    q = jax.random.normal(KEY, (2, 8, 16, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 16, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 16, 64))
    for mode in ("binary", "camformer"):
        o1 = attention(q, k, v, AttentionSpec(mode=mode, k_top=8, use_kernel=False))
        o2 = attention(q, k, v, AttentionSpec(mode=mode, k_top=8, use_kernel=True))
        assert jnp.abs(o1 - o2).max() < 1e-5, mode
