"""Chaos harness: fault injection against the serving stack.

Every leg drives a deterministic :class:`FaultPlan` (serving/faults.py)
through the engine/gateway and asserts the robustness contract:

  * every submitted request reaches a TERMINAL finish_reason (length /
    stop / cancelled / timeout / rejected / error) — no silent drops;
  * the page pool balances after every poll and at drain
    (``PagedKVCache.check()``: free + retained + used == n_pages - 1,
    refcounts exact, registry sound);
  * requests NOT touched by a fault produce bit-identical token streams
    to a fault-free run (keyed sampling: rng is (seed, rid, index), so
    rescheduling never changes values);
  * the engine keeps serving afterwards: a post-fault request matches a
    fresh engine token-for-token.

The dense legs run in the fast lane; the camformer / speculative /
tensor-parallel legs are ``slow`` (the CI ``chaos`` lane runs them with
XLA_FLAGS=--xla_force_host_platform_device_count=2)."""

import http.client
import json
import threading
import time

import jax
import pytest

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import (NO_FAULTS, FaultPlan, FaultSpec, QueueFullError,
                           RejectionError, Request, SamplingParams,
                           ServeEngine, parse_faults)
from repro.serving.gateway import EngineRunner, serve_background

_SLOW = pytest.mark.slow

_SAMPLING = dict(temperature=0.8, top_k=8, max_new=6)

_PROMPTS = [[3, 5, 8, 1], [4, 9, 2], [7, 7, 1, 3, 8], [11, 4, 6],
            [1, 2, 3, 4, 5], [9, 8, 7]]


def _cfg(backend="dense", **kw):
    return smoke_config("codeqwen1.5-7b").replace(attn_backend=backend, **kw)


def _engine(cfg, **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    return ServeEngine(md, cfg, params, **kw)


def _requests(n=6, **sampling_kw):
    kw = dict(_SAMPLING, **sampling_kw)
    return [Request(prompt=list(_PROMPTS[i % len(_PROMPTS)]),
                    sampling=SamplingParams(**kw), rid=i)
            for i in range(n)]


def _drive(eng, max_polls=2000):
    """Drain the engine, auditing the allocator after EVERY poll; a
    stalled engine (fault window never closing, lost wakeup) fails loudly
    instead of hanging the suite."""
    events = []
    polls = 0
    while eng.has_work or eng.has_pending:
        events.extend(eng.poll())
        eng.kv.check()
        polls += 1
        assert polls < max_polls, "engine stalled under fault injection"
    eng.kv.check()
    return events


def _baseline(cfg, reqs, **engine_kw):
    """Fault-free token streams for `reqs` (fresh engine, same rids —
    keyed sampling makes this the bit-exact reference)."""
    eng = _engine(cfg, **engine_kw)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    return {r.rid: tuple(r.tokens) for r in reqs}


def _terminal_map(reqs):
    return {r.rid: r.finish_reason for r in reqs}


# ---------------------------------------------------------------------------
# step.error: crash containment
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["sync", "overlap"])
def test_step_error_contained_and_survivors_bit_identical(mode):
    """A fused-step exception at tick 2 fails ONLY that tick's in-flight
    requests (finish_reason='error', pages freed); queued requests run
    afterwards bit-identically to a fault-free engine, and the engine
    itself keeps serving (a post-fault submit matches a fresh engine)."""
    cfg = _cfg()
    want = _baseline(cfg, _requests(), mode=mode)

    faults = FaultPlan([FaultSpec("step.error", start=2, stop=3)])
    reqs = _requests()
    eng = _engine(cfg, mode=mode, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)

    reasons = _terminal_map(reqs)
    assert all(reasons[i] is not None for i in range(6)), reasons
    # max_batch=2, max_new=6: rids 0/1 are the residents at tick 2
    assert reasons[0] == reasons[1] == "error"
    assert all(reqs[i].error for i in (0, 1))  # the cause is recorded
    assert eng.tick_errors == 1 and "InjectedFault" in eng.last_error
    for i in range(2, 6):  # untouched requests: bit-identical streams
        assert reasons[i] == "length"
        assert tuple(reqs[i].tokens) == want[i], i
    assert eng.sched._inflight_total == 0  # lost samples were settled

    # the engine is still a working engine: fresh traffic is unaffected
    post = Request(prompt=[2, 4, 6, 8], sampling=SamplingParams(**_SAMPLING),
                   rid=100)
    eng.submit(post)
    _drive(eng)
    ref = Request(prompt=[2, 4, 6, 8], sampling=SamplingParams(**_SAMPLING),
                  rid=100)
    ctrl = _engine(cfg, mode=mode)
    ctrl.submit(ref)
    _drive(ctrl)
    assert post.finish_reason == "length"
    assert tuple(post.tokens) == tuple(ref.tokens)


def test_repeated_step_errors_never_wedge():
    """Several distinct fault ticks in one run: every request still
    terminates, the pool still balances, and tick_errors counts each."""
    cfg = _cfg()
    faults = parse_faults("step.error@2,step.error@5,step.error@9")
    reqs = _requests(8)
    eng = _engine(cfg, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    assert all(r.finish_reason is not None for r in reqs)
    assert eng.tick_errors >= 1
    assert eng.sched._inflight_total == 0


# ---------------------------------------------------------------------------
# kv.exhaust: page-pool exhaustion window
# ---------------------------------------------------------------------------


def test_kv_exhaust_window_stalls_admission_then_completes():
    """While the allocator reports a dry pool, admission stalls (nothing
    crashes); once the window closes every request completes with
    token streams bit-identical to the fault-free run."""
    cfg = _cfg()
    want = _baseline(cfg, _requests())

    faults = FaultPlan([FaultSpec("kv.exhaust", start=1, stop=4)])
    reqs = _requests()
    eng = _engine(cfg, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    assert {r.rid: tuple(r.tokens) for r in reqs} == want
    assert all(r.finish_reason == "length" for r in reqs)
    assert eng.tick_errors == 0  # exhaustion is backpressure, not a crash


# ---------------------------------------------------------------------------
# tick.delay: straggler ticks change nothing but wall clock
# ---------------------------------------------------------------------------


def test_tick_delay_streams_identical():
    cfg = _cfg()
    want = _baseline(cfg, _requests(4))
    faults = FaultPlan(
        [FaultSpec("tick.delay", prob=0.5, delay_s=0.002)], seed=3)
    reqs = _requests(4)
    eng = _engine(cfg, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    assert faults.fired["tick.delay"] > 0  # the storm actually happened
    assert {r.rid: tuple(r.tokens) for r in reqs} == want


# ---------------------------------------------------------------------------
# deadlines / queue timeouts (injected clock: no wall-clock sleeping)
# ---------------------------------------------------------------------------


def test_deadline_expires_running_request():
    cfg = _cfg()
    t = {"now": 0.0}
    eng = _engine(cfg)
    eng.sched._clock = lambda: t["now"]
    doomed = Request(prompt=[3, 5, 8, 1],
                     sampling=SamplingParams(max_new=6, deadline_ms=50.0),
                     rid=0)
    steady = Request(prompt=[4, 9, 2], sampling=SamplingParams(max_new=6),
                     rid=1)
    eng.submit(doomed)
    eng.submit(steady)
    eng.poll()
    eng.poll()  # both admitted and decoding, clock frozen at t=0
    t["now"] = 1.0  # 1000ms later: doomed is 950ms past its deadline
    events = _drive(eng)
    assert doomed.finish_reason == "timeout"
    assert "deadline_ms" in doomed.error
    assert steady.finish_reason == "length" and len(steady.tokens) == 6
    assert eng.sched.timeouts == 1
    assert eng.sched._inflight_total == 0  # in-flight sample settled
    terminal = [e for e in events if e.finished and e.rid == 0]
    assert len(terminal) == 1 and terminal[0].finish_reason == "timeout"


def test_queue_timeout_applies_only_before_first_admission():
    cfg = _cfg()
    t = {"now": 0.0}
    eng = _engine(cfg, max_batch=1)
    eng.sched._clock = lambda: t["now"]
    first = Request(prompt=[3, 5, 8, 1],
                    sampling=SamplingParams(max_new=6,
                                            queue_timeout_ms=50.0),
                    rid=0)
    waiter = Request(prompt=[4, 9, 2],
                     sampling=SamplingParams(max_new=6,
                                             queue_timeout_ms=50.0),
                     rid=1)
    eng.submit(first)
    eng.submit(waiter)
    eng.poll()  # first admits (max_batch=1); waiter stays queued
    t["now"] = 0.2  # 200ms: waiter's queue wait exceeds its 50ms bound,
    #                 first is ADMITTED so its queue timeout no longer
    #                 applies — only a deadline_ms could expire it now
    _drive(eng)
    assert waiter.finish_reason == "timeout" and "queue" in waiter.error
    assert first.finish_reason == "length" and len(first.tokens) == 6
    assert eng.sched.timeouts == 1


# ---------------------------------------------------------------------------
# admission control: bounded queue, reject(), never-fit
# ---------------------------------------------------------------------------


def test_bounded_queue_and_public_reject():
    cfg = _cfg()
    eng = _engine(cfg, max_batch=1, max_queue=2)
    a, b, c = _requests(3, temperature=0.0)
    eng.submit(a)
    eng.submit(b)
    with pytest.raises(QueueFullError, match="queue full"):
        eng.submit(c)
    assert c.finish_reason is None and c not in eng.queue  # untouched
    assert eng.sched.rejections == 1

    # public load-shedding seam: reject a QUEUED request by rid
    out = eng.sched.reject(b.rid, "load shed by operator")
    assert out is not None and out.finish_reason == "rejected"
    assert b.finish_reason == "rejected"
    assert b.error == "load shed by operator"
    assert eng.sched.reject(999, "no such rid") is None
    assert eng.sched.rejections == 2

    _drive(eng)
    assert a.finish_reason == "length"


def test_never_fit_rejected_at_submit_with_reason():
    cfg = _cfg()
    eng = _engine(cfg, max_len=16)
    req = Request(prompt=[1] * 12, sampling=SamplingParams(max_new=8), rid=0)
    with pytest.raises(RejectionError, match="max_len 16"):
        eng.submit(req)
    assert not eng.queue
    assert eng.sched.never_fit(req) is not None
    ok = Request(prompt=[1, 2], sampling=SamplingParams(max_new=4), rid=1)
    assert eng.sched.never_fit(ok) is None


# ---------------------------------------------------------------------------
# fault-plan semantics (pure host, no model)
# ---------------------------------------------------------------------------


def test_fault_plan_windows_probability_and_parse():
    plan = parse_faults("step.error@3,kv.exhaust@1:4,tick.delay@0::p0.5:d0.05",
                        seed=7)
    by_point = {s.point: s for s in plan.specs}
    assert by_point["step.error"].start == 3
    assert by_point["step.error"].stop == 4  # @3 arms tick 3 only
    assert (by_point["kv.exhaust"].start, by_point["kv.exhaust"].stop) == (1, 4)
    td = by_point["tick.delay"]
    assert td.stop is None and td.prob == 0.5 and td.delay_s == 0.05

    plan.advance()  # tick 0
    assert not plan.active("kv.exhaust") and not plan.fires("step.error")
    plan.advance()  # tick 1
    assert plan.active("kv.exhaust")
    plan.advance(), plan.advance()  # tick 3
    assert plan.fires("step.error")
    plan.advance()  # tick 4: @3 armed tick 3 ONLY
    assert not plan.fires("step.error")
    # probabilistic draws are a pure function of (seed, point, call):
    # replaying the same plan produces the same firing sequence
    draws = [plan.delay("tick.delay") > 0 for _ in range(32)]
    replay = parse_faults("tick.delay@0::p0.5:d0.05", seed=7)
    for _ in range(4):
        replay.advance()
    assert [replay.delay("tick.delay") > 0 for _ in range(32)] == draws
    assert 0 < sum(draws) < 32  # p=0.5 actually splits

    assert not NO_FAULTS and not NO_FAULTS.active("kv.exhaust")
    assert parse_faults(None) is NO_FAULTS
    with pytest.raises(ValueError):
        SamplingParams(deadline_ms=-1.0)
    with pytest.raises(ValueError):
        SamplingParams(queue_timeout_ms=0.0)


# ---------------------------------------------------------------------------
# gateway: disconnect storms, 429/503 backpressure, stop() honesty
# ---------------------------------------------------------------------------


def _sse_post(port, spec, timeout=300):
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    conn.request("POST", "/v1/generate", body=json.dumps(spec),
                 headers={"Content-Type": "application/json"})
    resp = conn.getresponse()
    events, status = [], resp.status
    headers = dict(resp.getheaders())
    if status == 200:
        while True:
            line = resp.readline()
            if not line:
                break  # server dropped the connection (disconnect storm)
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            evt = json.loads(line[6:])
            events.append(evt)
            if evt.get("finished"):
                break
    else:
        events.append(json.loads(resp.read()))
    conn.close()
    return status, events, headers


def _wait_for(cond, timeout=60.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if cond():
            return True
        time.sleep(interval)
    return cond()


def test_gateway_disconnect_storm_contained():
    """The gateway drops 4 client connections mid-stream (times-capped
    ``gateway.disconnect``); the dropped requests cancel server-side and
    free their pages, the survivors finish, and the engine serves fresh
    traffic afterwards with a balanced pool."""
    faults = FaultPlan(
        [FaultSpec("gateway.disconnect", prob=1.0, times=4)])
    eng = _engine(_cfg(), max_batch=3, faults=faults)
    handle = serve_background(eng)
    try:
        results = [None] * 6
        spec = {"prompt": [3, 5, 8, 1], "max_new": 6, "temperature": 0.8,
                "top_k": 8}

        def client(i):
            try:
                results[i] = _sse_post(handle.port, dict(spec))
            except OSError:  # reset mid-read: same thing as a drop
                results[i] = (200, [], {})

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(120)
        dropped = sum(1 for st, evts, _ in results
                      if st == 200 and not (evts and evts[-1].get("finished")))
        finished = sum(1 for st, evts, _ in results
                       if st == 200 and evts and evts[-1].get("finished"))
        assert dropped == 4 and finished == 2, results
        assert _wait_for(lambda: not (eng.has_work or eng.has_pending))
        eng.kv.check()
        snap = handle.runner.metrics.snapshot()
        assert snap["requests"]["cancelled"] == 4
        assert snap["requests"]["completed"] == 2

        # the storm is spent (times=4): fresh traffic completes normally
        st, evts, _ = _sse_post(handle.port, dict(spec))
        assert st == 200 and evts[-1]["finished"]
        assert evts[-1]["finish_reason"] == "length"
        assert _wait_for(lambda: not (eng.has_work or eng.has_pending))
        eng.kv.check()
    finally:
        handle.stop()


def test_gateway_backpressure_429_and_503():
    """Admission vetoes map to honest HTTP: a full bounded queue is 429
    + Retry-After (retryable), a request the engine can NEVER serve is
    503; neither ever reaches the engine thread."""
    eng = _engine(_cfg(), n_pages=3, max_queue=0)  # 2 usable pages
    handle = serve_background(eng)
    try:
        # never-fit beats queue-full: 503, not 429
        st, evts, _ = _sse_post(
            handle.port, {"prompt": [1, 2, 3], "max_new": 30})
        assert st == 503
        assert evts[0]["finish_reason"] == "rejected"
        assert "pool has 2" in evts[0]["error"]
        # fits the pool but the queue is full (max_queue=0): 429
        st, evts, headers = _sse_post(
            handle.port, {"prompt": [1, 2, 3], "max_new": 1})
        assert st == 429
        assert headers.get("Retry-After") == "1"
        assert evts[0]["retry_after_s"] == 1
        assert "queue full" in evts[0]["error"]
        snap = handle.runner.metrics.snapshot()
        assert snap["requests"]["rejected"] == 2
        assert snap["requests"]["submitted"] == 0  # vetoed pre-submit
    finally:
        handle.stop()


def test_runner_stop_timeout_reports_failure(caplog):
    """A stop() whose join times out must say so (return False + log),
    not report a clean shutdown while the thread still runs."""

    class Stuck(EngineRunner):
        def run(self):  # ignores _stopping long enough to miss the join
            time.sleep(0.5)

    eng = _engine(_cfg())
    runner = Stuck(eng)
    runner.start()
    with caplog.at_level("ERROR", logger="repro.serving.gateway"):
        assert runner.stop(timeout=0.05) is False
    assert any("failed to stop" in r.message for r in caplog.records)
    runner.join(5)  # let the stuck thread drain before the test exits
    assert runner.stop(timeout=5) is True  # once dead, stop reports clean


# ---------------------------------------------------------------------------
# slow legs: camformer, speculative rollback, tensor-parallel containment
# ---------------------------------------------------------------------------


@_SLOW
@pytest.mark.parametrize("backend", ["camformer"])
def test_chaos_matrix_camformer(backend):
    cfg = _cfg(backend)
    want = _baseline(cfg, _requests())
    faults = parse_faults("step.error@3,kv.exhaust@5:7")
    reqs = _requests()
    eng = _engine(cfg, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    reasons = _terminal_map(reqs)
    assert all(v is not None for v in reasons.values())
    assert eng.tick_errors == 1
    for r in reqs:
        if r.finish_reason == "length":
            assert tuple(r.tokens) == want[r.rid], r.rid


@_SLOW
def test_spec_exhaustion_rollback_preempts_and_streams_identical():
    """kv.exhaust during speculative decoding: a rejected-suffix rollback
    whose boundary fork cannot allocate preempts the slot instead of
    handing it a shared page; resume is token-exact, so the full run
    still matches the fault-free speculative engine bit-for-bit."""
    cfg = _cfg("dense")
    kw = dict(spec_k=2, max_batch=2, n_pages=9)
    want = _baseline(cfg, _requests(4, temperature=0.0), **kw)
    faults = FaultPlan([FaultSpec("kv.exhaust", start=2, stop=5)])
    reqs = _requests(4, temperature=0.0)
    eng = _engine(cfg, faults=faults, **kw)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    assert {r.rid: tuple(r.tokens) for r in reqs} == want
    assert all(r.finish_reason == "length" for r in reqs)


@_SLOW
@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (XLA_FLAGS="
                           "--xla_force_host_platform_device_count=2)")
def test_tp2_step_error_contained():
    """Crash containment under tensor parallelism: the mesh-wide fused
    step dies, the tick's requests fail, the replicated token buffer
    resets, and the sharded engine keeps serving bit-identically."""
    cfg = _cfg()
    # tp=1 reference is valid: test_sharded pins tp-degree token identity
    want = _baseline(cfg, _requests(4))
    faults = FaultPlan([FaultSpec("step.error", start=2, stop=3)])
    reqs = _requests(4)
    eng = _engine(cfg, tp=2, faults=faults)
    for r in reqs:
        eng.submit(r)
    _drive(eng)
    reasons = _terminal_map(reqs)
    assert all(v is not None for v in reasons.values())
    assert reasons[0] == reasons[1] == "error"
    assert eng.tick_errors == 1
    for i in (2, 3):
        assert reasons[i] == "length"
        assert tuple(reqs[i].tokens) == want[i]
