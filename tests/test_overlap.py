"""Overlapped serving loop correctness: the dispatch-ahead engine must be
token-for-token identical to the synchronous loop (same per-request rng,
same per-request tick schedule) across backends, including under
continuous chunked prefill, one-tick-deferred stop/length finishes,
priority preemption, and mid-stream cancellation — plus the
one-readback-per-decode-tick invariant the overlap win rests on.

Per the decode tolerance policy: every comparison here is SAME-PATH
(identical dispatch structure, only the readback timing differs), so
equality is exact for every backend — no tolerances."""

import jax
import pytest

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import (Request, RequestState, SamplingParams,
                           ServeEngine)

_SLOW = pytest.mark.slow


def _cfg(backend=None, layer_backends=None, **kw):
    cfg = smoke_config("codeqwen1.5-7b")
    if layer_backends:
        kw["n_layers"] = max(cfg.n_layers, len(layer_backends))
    return cfg.replace(attn_backend=backend, layer_backends=layer_backends,
                       **kw)


def _engine(cfg, **kw):
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 3)
    kw.setdefault("max_len", 64)
    kw.setdefault("page_size", 8)
    return ServeEngine(md, cfg, params, **kw)


def _drained(eng):
    return (eng.kv.free_pages == eng.kv.n_pages - 1
            and eng.sched._inflight_total == 0)


# ---------------------------------------------------------------------------
# the overlap-equivalence matrix (ISSUE 4 acceptance): overlapped mode ==
# sync mode token-for-token for dense / camformer / mixed stacks, with
# continuous chunked prefill and COW prefix sharing in the mix


@pytest.mark.parametrize("backend,layer_backends", [
    ("dense", None),
    pytest.param("camformer", None, marks=_SLOW),
    pytest.param(None, ("dense", "camformer"), marks=_SLOW),
])
def test_overlap_equals_sync_token_for_token(backend, layer_backends):
    cfg = _cfg(backend, layer_backends)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    shared = list(range(30, 42))  # shared prefix: COW sharing + defer
    prompts = ([shared + [i, i + 2] for i in (3, 7)]
               + [[9, 1, 4], [2, 2, 6, 1, 8]])  # more requests than slots

    def gen(mode):
        # prefill_slice=8: admission prefills in page-sized chunks across
        # ticks while resident slots keep decoding (continuous batching)
        eng = ServeEngine(md, cfg, params, max_batch=3, max_len=64,
                          page_size=8, mode=mode, prefill_slice=8)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p),
                               sampling=SamplingParams(max_new=5), rid=i))
        got, finished = {}, {}
        for out in eng.stream():
            got.setdefault(out.rid, []).append(out.token)
            finished[out.rid] = out.finished
        assert _drained(eng)
        return got, finished

    want, want_done = gen("sync")
    got, got_done = gen("overlap")
    assert got == want  # token-for-token, exact, every backend
    assert all(got_done.values()) and all(want_done.values())
    assert set(got) == set(range(len(prompts)))


# ---------------------------------------------------------------------------
# one-tick-deferred visibility: stop-token and max_new finishes never
# surface extra tokens (the overlapped loop's zombie tick is discarded)


@pytest.mark.parametrize("mode", ["sync", "overlap"])
def test_stop_token_deferred_visibility_no_extra_tokens(mode):
    probe = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=6))
    eng = _engine(_cfg(), mode=mode)
    eng.submit(probe)
    eng.run()
    assert len(probe.tokens) == 6  # max_new finish, exact count
    stop_tok = probe.tokens[2]

    eng2 = _engine(_cfg(), mode=mode)
    req = Request(prompt=[5, 9, 2],
                  sampling=SamplingParams(max_new=6, stop=(stop_tok,)))
    outs = list(eng2.stream(req))
    # the stop finish is only VISIBLE one tick after it was dispatched in
    # overlap mode — the zombie tick's sample must be discarded, never
    # surfaced as a token or an event
    assert req.finish_reason == "stop"
    assert req.tokens == probe.tokens[:3]  # stop token kept, nothing after
    assert [o.token for o in outs] == req.tokens
    assert [o.finished for o in outs] == [False, False, True]
    assert _drained(eng2)


def test_max_new_finish_is_plan_exact_under_overlap():
    """Length finishes are host-plannable: the overlapped loop must not
    even dispatch a zombie tick for them — dispatched count == surfaced
    count == max_new."""
    eng = _engine(_cfg(), mode="overlap")
    reqs = [Request(prompt=[5, 9, 2 + i], sampling=SamplingParams(max_new=n))
            for i, n in enumerate((1, 3, 6))]
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r, n in zip(reqs, (1, 3, 6)):
        assert len(r.tokens) == n and r.finish_reason == "length"
    # ticks: all three decode in lockstep; the longest needs max_new-1=5
    # decode dispatches after its prefill-sampled first token
    assert eng.ticks == 5
    assert _drained(eng)


# ---------------------------------------------------------------------------
# exactly one host<->device readback per decode tick (sampled token ids)


def test_single_readback_per_decode_tick():
    eng = _engine(_cfg(), mode="overlap")
    eng.submit(Request(prompt=[5, 9, 2, 4],
                       sampling=SamplingParams(max_new=6)))
    for out in eng.stream():
        pass
    # 1 prefill-completion read (first token) + one read per decode tick
    assert eng.ticks == 5
    assert eng.readbacks == 1 + eng.ticks
    # the double-buffered token state stays on device between ticks
    assert isinstance(eng._tok_buf, jax.Array)


def test_sampling_is_fused_into_the_step_jit():
    """The decode jit's first output is the sampled ids themselves —
    sampling happens inside the step, not on logits read back host-side."""
    eng = _engine(_cfg(), mode="sync")
    eng.submit(Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=3)))
    plan = eng.sched.plan_tick()
    inflight = eng._dispatch(plan)
    tok = inflight.decode_tok
    assert tok.shape == (eng.max_batch,) and tok.dtype.name == "int32"
    eng._collect(inflight)
    eng.run()


# ---------------------------------------------------------------------------
# continuous chunked-prefill batching: a joining request prefills in
# page-sized chunks across ticks while resident slots keep decoding


def test_chunked_prefill_interleaves_with_decode():
    eng = _engine(_cfg(), max_batch=2, mode="sync", prefill_slice=8)
    a = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=12))
    eng.submit(a)
    eng.step()  # a admitted (whole 3-token prompt is one chunk) + decoding
    assert a.state is RequestState.DECODING
    b = Request(prompt=list(range(100, 130)),  # 30 tokens: 4 chunks of 8
                sampling=SamplingParams(max_new=4))
    eng.submit(b)
    for expect_prefilling in (True, True, True, False):
        before = len(a.tokens)
        eng.step()
        assert len(a.tokens) == before + 1  # a KEPT decoding every tick
        assert (b.state is RequestState.PREFILLING) == expect_prefilling
    assert b.state is RequestState.DECODING and len(b.tokens) >= 1
    eng.run()
    assert len(a.tokens) == 12 and len(b.tokens) == 4
    assert _drained(eng)


# ---------------------------------------------------------------------------
# preemption + mid-stream cancel under the overlapped loop


@pytest.mark.parametrize("backend", [
    "dense", pytest.param("camformer", marks=_SLOW)])
def test_preemption_equivalence_across_modes(backend):
    """Page-pressure preemption from an identical mid-generation state
    resumes to the same final tokens in sync and overlapped mode (the
    recompute resume path is the same in both)."""
    cfg = _cfg(backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))

    def gen(mode):
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=32,
                          page_size=8, n_pages=5, prefix_sharing=False,
                          mode=mode)
        lo = Request(prompt=[1, 2, 3, 4, 5, 6],
                     sampling=SamplingParams(max_new=18), rid=0, priority=0)
        eng.submit(lo)
        eng.step()  # sync ticks: identical mid-generation state either mode
        eng.step()
        assert lo.state is RequestState.DECODING and len(lo.tokens) >= 2
        kept = list(lo.tokens)
        hi = Request(prompt=[9, 8, 7, 6, 5, 4],
                     sampling=SamplingParams(max_new=18), rid=1, priority=5)
        eng.submit(hi)
        done = eng.run()  # mode-specific loop: hi preempts lo, lo resumes
        assert {r.rid for r in done} == {0, 1}
        assert all(len(r.tokens) == 18 for r in done)
        assert lo.tokens[:len(kept)] == kept  # resume continued, no restart
        assert _drained(eng)
        return {r.rid: r.tokens for r in done}

    assert gen("overlap") == gen("sync")


def test_cancel_with_inflight_dispatched_tick():
    """cancel() of a slot whose tick is dispatched-but-unread: the pages
    free immediately, in-flight samples for it are discarded (no token
    events after the cancel record), and the surviving slot's stream is
    unperturbed (row independence)."""
    cfg = _cfg()
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))

    def build():
        eng = ServeEngine(md, cfg, params, max_batch=2, max_len=64,
                          page_size=8, mode="overlap")
        a = Request(prompt=[1, 2, 3], sampling=SamplingParams(max_new=10),
                    rid=0)
        b = Request(prompt=[4, 5, 6], sampling=SamplingParams(max_new=10),
                    rid=1)
        return eng, a, b

    eng, a, b = build()
    stream = eng.stream(a, b)
    events = []
    while len(a.tokens) < 3:  # overlap: a tick beyond this is in flight
        events.append(next(stream))
    assert eng.sched._inflight_total > 0  # the dispatched-but-unread tick
    out = eng.cancel(a.rid)
    assert out.finished and a.state is RequestState.CANCELLED
    assert eng.kv.used_pages < 2 * eng.kv.table.shape[1]  # pages freed NOW
    n_at_cancel = len(a.tokens)
    remaining = list(stream)  # drain
    assert len(a.tokens) == n_at_cancel  # in-flight samples discarded
    assert not any(o.rid == a.rid for o in remaining)  # no a-events after
    assert b.finish_reason == "length" and len(b.tokens) == 10
    assert _drained(eng)

    # row independence: b's stream matches a run without the cancel
    ctrl, _, cb = build()
    ctrl.submit(cb)
    ctrl.run()
    assert b.tokens == cb.tokens


def test_cancel_reaches_drain_released_request():
    """A request whose slot was drain-released at plan time (final token
    dispatched but unread) is still cancellable: cancel() must find it in
    the retiring set, not silently return None and later surface a
    finished event."""
    eng = _engine(_cfg(), mode="overlap")
    a = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=4), rid=0)
    stream = eng.stream(a)
    events = [next(stream)]
    while len(a.tokens) < 3:  # final (4th) token dispatched ahead, unread
        events.append(next(stream))
    # force the drain-release plan pass with the final token in flight
    eng.sched._drain_dispatched()
    assert a not in eng.active and a not in eng.queue
    assert eng.sched._inflight_total > 0
    out = eng.cancel(a.rid)
    assert out is not None and out.finished
    assert a.state is RequestState.CANCELLED
    remaining = list(stream)
    assert not any(o.rid == a.rid for o in remaining)  # no late events
    assert len(a.tokens) == 3  # the in-flight final token was discarded
    assert not eng.sched._retiring and _drained(eng)
