"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV at the end (scaffold contract);
human-readable tables above it.

``--smoke`` runs the CI-sized subset: analytic energy numbers, the
roofline report (no-op without dry-run artifacts), and the paged-decode
engine tick — no training loops or large host-timed attention sweeps.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset (no training / large sweeps)")
    args = ap.parse_args()

    csv_rows = []
    from benchmarks import fig5_energy, paged_decode, roofline

    csv_rows = fig5_energy.run(csv_rows)
    csv_rows = paged_decode.run(csv_rows)
    csv_rows = roofline.run(csv_rows)
    if not args.smoke:
        from benchmarks import table2_perf, table34_accuracy

        csv_rows = table2_perf.run(csv_rows)
        csv_rows = table34_accuracy.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")


if __name__ == '__main__':
    main()
