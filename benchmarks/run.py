"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV at the end (scaffold contract);
human-readable tables above it.
"""

import sys


def main() -> None:
    csv_rows = []
    from benchmarks import fig5_energy, roofline, table2_perf, table34_accuracy

    csv_rows = table2_perf.run(csv_rows)
    csv_rows = fig5_energy.run(csv_rows)
    csv_rows = table34_accuracy.run(csv_rows)
    csv_rows = roofline.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")


if __name__ == '__main__':
    main()
