"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV at the end (scaffold contract);
human-readable tables above it.  ``--json PATH`` additionally writes the
rows as JSON (the CI bench-smoke lane uploads one ``BENCH_<backend>.json``
per attention backend so the perf trajectory accumulates as artifacts).

``--smoke`` runs the CI-sized subset: analytic energy numbers, the
roofline report (no-op without dry-run artifacts), and the paged-decode
engine tick per backend — no training loops or large host-timed attention
sweeps.  ``--backend`` narrows the paged-decode sweep to one backend.
"""

import argparse

from repro.utils import write_json_atomic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset (no training / large sweeps)")
    ap.add_argument("--backend", default=None,
                    help="restrict the paged-decode sweep to one backend "
                         "(default: dense,camformer comparison)")
    ap.add_argument("--json", default=None,
                    help="also write the CSV rows to this JSON file")
    args = ap.parse_args()

    backends = (tuple(args.backend.split(",")) if args.backend
                else ("dense", "camformer"))
    csv_rows = []
    from benchmarks import fig5_energy, paged_decode, roofline

    csv_rows = fig5_energy.run(csv_rows)
    csv_rows = paged_decode.run(csv_rows, backends=backends)
    csv_rows = roofline.run(csv_rows)
    if not args.smoke:
        from benchmarks import table2_perf, table34_accuracy

        csv_rows = table2_perf.run(csv_rows)
        csv_rows = table34_accuracy.run(csv_rows)

    print("\nname,us_per_call,derived")
    for name, val, derived in csv_rows:
        print(f"{name},{val},{derived}")
    if args.json:
        # atomic (write-temp + rename): a timed-out CI lane can never
        # upload a truncated BENCH_*.json artifact
        write_json_atomic(args.json,
                          [{"name": n, "us_per_call": v, "derived": d}
                           for n, v, d in csv_rows])
        print(f"wrote {args.json} ({len(csv_rows)} rows)")


if __name__ == '__main__':
    main()
