"""Traffic-SLO load benchmark: Poisson arrivals against the serving stack.

Turns "overlap >= sync ticks/s" into the metric that matters under live
traffic: TTFT (submit -> first token) and TPOT (inter-token) percentiles,
and goodput-under-SLO — completed requests per second whose TTFT *and*
mean TPOT met the SLO — under continuous-batching admission, preemption,
and COW prefix sharing (a configurable fraction of requests opens with a
shared system prompt).

Three drivers, one report:

  * ``--inproc``   — submit straight onto the ``EngineRunner`` thread (no
    sockets): the deterministic CI lane.
  * ``--url URL``  — drive an already-running gateway over HTTP/SSE.
  * (default)      — self-host a gateway on a free port and drive it over
    real HTTP/SSE.

``--smoke`` shrinks the workload to CI size and asserts the report is
well-formed, goodput > 0, and p99 TTFT is bounded (post-warmup; the jit
compile is excluded).  ``--json`` writes the report atomically
(write-temp + rename) so a timed-out CI lane never uploads a truncated
``BENCH_slo_*.json`` artifact.

Standalone:

    PYTHONPATH=src:. python benchmarks/serve_slo.py --smoke --inproc \\
        --backend camformer --json BENCH_slo_camformer.json
"""

import argparse
import asyncio
import json
import threading
import time
from urllib.parse import urlparse

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine
from repro.serving.gateway import EngineRunner, serve_background
from repro.utils import write_json_atomic

# short / medium / long prompt-length mix: (lo, hi, weight), lengths are
# TAIL tokens appended after the (optional) shared system prompt
PROMPT_MIX = ((2, 8, 0.6), (8, 24, 0.3), (24, 48, 0.1))

REQUIRED_KEYS = (
    "backend",
    "driver",
    "n_requests",
    "completed",
    "cancelled",
    "timed_out",
    "rejected",
    "errored",
    "unfinished",
    "wall_s",
    "throughput_rps",
    "tokens_per_s",
    "ttft_ms",
    "tpot_ms",
    "slo",
    "slo_attained_frac",
    "goodput_rps",
    "preemptions",
    "prefix_hit_rate",
    "engine",
)


def engine_kwargs(args) -> dict:
    """ALL ServeEngine kwargs the drivers forward, as ONE dict — new
    engine knobs (``tp``, ``spec_k``, ...) ride uniformly instead of
    growing positionally at every call site."""
    return {
        "max_batch": args.max_batch,
        "max_len": args.max_len,
        "page_size": args.page_size,
        "n_pages": args.n_pages,
        "mode": args.mode,
        "prefill_slice": args.page_size,  # one fixed-size prefill chunk/jit
        "tp": args.tp,
        "spec_k": args.spec_k,
        "max_queue": args.max_queue,
    }


def build_engine(args) -> ServeEngine:
    cfg = smoke_config(args.arch).replace(attn_backend=args.backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    return ServeEngine(md, cfg, params, **engine_kwargs(args))


def _shared_prompt(args):
    return [7 + (i % 50) for i in range(args.shared_len)]


def build_workload(args, vocab: int):
    """Poisson arrivals over the prompt-length mix; ``--shared-frac`` of
    requests open with a common system prompt (COW prefix-sharing hits)."""
    rng = np.random.default_rng(args.seed)
    shared = _shared_prompt(args)
    lows = np.array([m[0] for m in PROMPT_MIX])
    highs = np.array([m[1] for m in PROMPT_MIX])
    weights = np.array([m[2] for m in PROMPT_MIX], dtype=float)
    weights /= weights.sum()
    t = 0.0
    work = []
    for _ in range(args.requests):
        t += float(rng.exponential(1.0 / args.rate))
        band = int(rng.choice(len(PROMPT_MIX), p=weights))
        tail_len = int(rng.integers(lows[band], highs[band] + 1))
        tail = [int(x) for x in rng.integers(1, vocab, size=tail_len)]
        prompt = tail
        if rng.random() < args.shared_frac:
            prompt = shared + tail
        # clamp so prompt+max_new always fits max_len (admissible by
        # construction: the benchmark measures latency, not rejections)
        prompt = prompt[: max(1, args.max_len - args.max_new)]
        work.append({"arrival_s": t, "prompt": prompt, "max_new": args.max_new})
    return work


def _sampling(args) -> SamplingParams:
    return SamplingParams(
        temperature=args.temperature,
        top_k=8,
        max_new=args.max_new,
        deadline_ms=args.deadline_ms,
    )


def _warmup(engine, args):
    """Compile every jit the measured run will hit — the prefill chunk,
    both decode variants, and the COW boundary fork (two requests sharing
    a system prompt) — so TTFT measures serving, not compilation."""
    shared = _shared_prompt(args)
    for tail in ([3, 5], [8, 1]):
        engine.submit(Request(prompt=shared + tail, sampling=_sampling(args)))
    engine.run()


# ---------------------------------------------------------------------------
# drivers: each returns (records, wall_s, server_view)
# records: [{"arrival": t, "times": [t_tok, ...], "finish": reason}]
# server_view: {"preemptions", "prefix_hit_rate", "engine": {...}}
# ---------------------------------------------------------------------------


def _server_view(engine, metrics) -> dict:
    return {
        "preemptions": engine.preemptions,
        "prefix_hit_rate": metrics.snapshot()["requests"]["prefix_hit_rate"],
        "engine": {
            "ticks": engine.ticks,
            "readbacks": engine.readbacks,
            "blocked_s": engine.blocked_s,
            "peak_pages": engine.peak_pages,
            "pool_pages": engine.kv.n_pages - 1,
            "tp": engine.tp,
            # TTFT attribution: how much of the run was prefill-path
            # work (chunk ticks / prompt tokens materialized) — a TTFT
            # regression with flat prefill counters is a decode/queueing
            # problem, a rising one sits on the chunked-prefill path
            "prefill_tokens": engine.prefill_tokens,
            "prefill_ticks": engine.prefill_ticks,
        },
    }


def drive_inproc(args, workload):
    engine = build_engine(args)
    _warmup(engine, args)
    runner = EngineRunner(engine, idle_wait_s=0.002)
    runner.start()
    records = []
    t0 = time.perf_counter()
    for w in workload:
        wait = t0 + w["arrival_s"] - time.perf_counter()
        if wait > 0:
            time.sleep(wait)
        rec = {"arrival": time.perf_counter(), "times": [], "finish": None}
        done = threading.Event()

        def sink(out, rec=rec, done=done):
            if out.token is not None:
                rec["times"].append(time.perf_counter())
            if out.finished:
                rec["finish"] = out.finish_reason
                done.set()

        runner.submit(
            Request(prompt=list(w["prompt"]), sampling=_sampling(args)), sink
        )
        records.append((rec, done))
    for _, done in records:
        done.wait(timeout=600)
    wall = time.perf_counter() - t0
    view = _server_view(engine, runner.metrics)
    runner.stop()
    return [rec for rec, _ in records], wall, view


async def _sse_generate(host, port, spec):
    """One HTTP/SSE generation; returns the per-token wall-clock record."""
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps(spec).encode()
    writer.write(
        b"POST /v1/generate HTTP/1.1\r\n"
        + f"Host: {host}:{port}\r\n".encode()
        + f"Content-Length: {len(body)}\r\n".encode()
        + b"Content-Type: application/json\r\n\r\n"
        + body
    )
    await writer.drain()
    rec = {"arrival": time.perf_counter(), "times": [], "finish": None}
    try:
        await reader.readuntil(b"\r\n\r\n")
        while True:
            line = await reader.readline()
            if not line:
                break
            line = line.strip()
            if not line.startswith(b"data: "):
                continue
            evt = json.loads(line[6:])
            if evt.get("token") is not None:
                rec["times"].append(time.perf_counter())
            if evt.get("finished"):
                rec["finish"] = evt.get("finish_reason")
                break
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
    return rec


async def _fetch_json(host, port, path):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(
        f"GET {path} HTTP/1.1\r\nHost: {host}:{port}\r\n\r\n".encode()
    )
    await writer.drain()
    head = await reader.readuntil(b"\r\n\r\n")
    length = 0
    for raw in head.decode("latin-1").split("\r\n"):
        name, sep, value = raw.partition(":")
        if sep and name.strip().lower() == "content-length":
            length = int(value.strip())
    body = await reader.readexactly(length)
    writer.close()
    return json.loads(body)


async def _drive_url(args, workload, host, port):
    spec_base = {
        "temperature": args.temperature,
        "top_k": 8,
        "max_new": args.max_new,
    }
    if args.deadline_ms is not None:
        spec_base["deadline_ms"] = args.deadline_ms
    # warmup request outside the clock (jit compiles on first traffic)
    await _sse_generate(host, port, dict(spec_base, prompt=[3, 5, 8, 1]))

    t0 = time.perf_counter()

    async def one(w):
        await asyncio.sleep(max(0.0, t0 + w["arrival_s"] - time.perf_counter()))
        return await _sse_generate(host, port, dict(spec_base, prompt=w["prompt"]))

    records = await asyncio.gather(*(one(w) for w in workload))
    wall = time.perf_counter() - t0
    metrics = await _fetch_json(host, port, "/metrics")
    view = {
        "preemptions": metrics["engine"]["preemptions"],
        "prefix_hit_rate": metrics["requests"]["prefix_hit_rate"],
        "engine": {
            k: metrics["engine"].get(k)
            for k in (
                "ticks",
                "readbacks",
                "blocked_s",
                "peak_pages",
                "pool_pages",
                "tp",
            )
        },
    }
    return list(records), wall, view


def drive_gateway(args, workload):
    if args.url:
        u = urlparse(args.url)
        return asyncio.run(_drive_url(args, workload, u.hostname, u.port))
    engine = build_engine(args)
    _warmup(engine, args)
    handle = serve_background(engine)
    try:
        return asyncio.run(
            _drive_url(args, workload, handle.gateway.host, handle.port)
        )
    finally:
        handle.stop()


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


def _pcts(samples):
    if not samples:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "n": 0}
    arr = np.asarray(samples)
    return {
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
        "n": len(samples),
    }


def build_report(args, records, wall, view, driver):
    ttfts, tpots, per_req_ok, tokens = [], [], [], 0
    completed = cancelled = timed_out = rejected = errored = unfinished = 0
    for rec in records:
        # every request must reach a terminal finish_reason; the full
        # breakdown (request.py docstring table) lands in the report so
        # a deadline lane can gate on the timed-out fraction
        if rec["finish"] is None:
            unfinished += 1
            continue
        if rec["finish"] == "cancelled":
            cancelled += 1
            continue
        if rec["finish"] == "timeout":
            timed_out += 1
            continue
        if rec["finish"] == "rejected":
            rejected += 1
            continue
        if rec["finish"] == "error":
            errored += 1
            continue
        completed += 1
        tokens += len(rec["times"])
        if not rec["times"]:
            continue
        ttft = (rec["times"][0] - rec["arrival"]) * 1e3
        gaps = [
            (b - a) * 1e3 for a, b in zip(rec["times"], rec["times"][1:])
        ]
        tpot = float(np.mean(gaps)) if gaps else 0.0
        ttfts.append(ttft)
        if gaps:
            tpots.append(tpot)
        per_req_ok.append(
            ttft <= args.slo_ttft_ms and (not gaps or tpot <= args.slo_tpot_ms)
        )
    attained = sum(per_req_ok)
    return {
        "bench": "serve_slo",
        "backend": args.backend,
        "driver": driver,
        "engine_mode": args.mode,
        "n_requests": len(records),
        "rate_rps": args.rate,
        "shared_frac": args.shared_frac,
        "shared_len": args.shared_len,
        "max_new": args.max_new,
        "seed": args.seed,
        "completed": completed,
        "cancelled": cancelled,
        "timed_out": timed_out,
        "rejected": rejected,
        "errored": errored,
        "unfinished": unfinished,
        "timed_out_frac": timed_out / max(len(records), 1),
        "deadline_ms": args.deadline_ms,
        "wall_s": wall,
        "throughput_rps": completed / max(wall, 1e-9),
        "tokens_per_s": tokens / max(wall, 1e-9),
        "ttft_ms": _pcts(ttfts),
        "tpot_ms": _pcts(tpots),
        "slo": {"ttft_ms": args.slo_ttft_ms, "tpot_ms": args.slo_tpot_ms},
        "slo_attained": attained,
        "slo_attained_frac": attained / max(completed, 1),
        "goodput_rps": attained / max(wall, 1e-9),
        "preemptions": view["preemptions"],
        "prefix_hit_rate": view["prefix_hit_rate"],
        "engine": view["engine"],
    }


def print_report(r):
    print(
        f"\n== serve_slo [{r['backend']}] {r['driver']} driver: "
        f"{r['n_requests']} reqs @ {r['rate_rps']:.1f} rps "
        f"(shared-prefix frac {r['shared_frac']:.0%}) =="
    )
    t, p = r["ttft_ms"], r["tpot_ms"]
    print(
        f"  TTFT p50 {t['p50']:.1f} ms | p99 {t['p99']:.1f} ms    "
        f"TPOT p50 {p['p50']:.1f} ms | p99 {p['p99']:.1f} ms"
    )
    print(
        f"  completed {r['completed']}/{r['n_requests']} in {r['wall_s']:.2f}s "
        f"({r['throughput_rps']:.2f} rps, {r['tokens_per_s']:.1f} tok/s)"
    )
    other = (
        r["cancelled"] + r["timed_out"] + r["rejected"] + r["errored"] + r["unfinished"]
    )
    if other:
        print(
            f"  non-completions: {r['timed_out']} timed out "
            f"({r['timed_out_frac']:.0%} of submits), "
            f"{r['rejected']} rejected, {r['errored']} errored, "
            f"{r['cancelled']} cancelled, {r['unfinished']} unfinished"
        )
    print(
        f"  goodput under SLO (ttft<={r['slo']['ttft_ms']:.0f}ms, "
        f"tpot<={r['slo']['tpot_ms']:.0f}ms): {r['goodput_rps']:.2f} rps "
        f"({r['slo_attained_frac']:.0%} of completions)"
    )
    print(
        f"  preemptions {r['preemptions']}, prefix hit rate "
        f"{r['prefix_hit_rate']:.0%}, peak pages "
        f"{r['engine']['peak_pages']}/{r['engine']['pool_pages']}, "
        f"{r['engine']['ticks']} ticks / {r['engine']['readbacks']} readbacks"
    )
    e = r["engine"]
    print(
        f"  prefill path: {e['prefill_tokens']} prompt tokens over "
        f"{e['prefill_ticks']} chunk ticks (TTFT attribution)"
    )


def check_report(r, *, smoke_ttft_bound_ms):
    """--smoke gate: well-formed report, every request terminal, nonzero
    goodput, bounded p99 TTFT.  Timed-out requests are allowed (a
    ``--deadline-ms`` lane expects some) — but silent drops, crashes,
    and cancellations are not."""
    missing = [k for k in REQUIRED_KEYS if k not in r]
    assert not missing, f"SLO report missing keys: {missing}"
    assert r["unfinished"] == 0, (
        f"{r['unfinished']} requests never reached a terminal finish_reason"
    )
    assert r["completed"] > 0, "no request completed"
    assert r["cancelled"] == 0, f"{r['cancelled']} requests cancelled"
    assert r["errored"] == 0, f"{r['errored']} requests crashed"
    assert r["rejected"] == 0, f"{r['rejected']} requests rejected"
    assert r["goodput_rps"] > 0, (
        f"zero goodput: every completion violated the smoke SLO "
        f"(ttft p99 {r['ttft_ms']['p99']:.0f} ms, "
        f"tpot p99 {r['tpot_ms']['p99']:.0f} ms)"
    )
    assert r["ttft_ms"]["p99"] <= smoke_ttft_bound_ms, (
        f"p99 TTFT {r['ttft_ms']['p99']:.0f} ms exceeds the smoke bound "
        f"{smoke_ttft_bound_ms:.0f} ms"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--backend", default="dense")
    ap.add_argument("--inproc", action="store_true", help="no sockets: CI lane")
    ap.add_argument("--url", default=None, help="drive a running gateway")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0, help="arrival rate (rps)")
    ap.add_argument("--shared-frac", type=float, default=0.5)
    ap.add_argument("--shared-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--mode", default="overlap", choices=("overlap", "sync"))
    ap.add_argument(
        "--tp",
        type=int,
        default=1,
        help="tensor-parallel degree (head-sharded page pools; needs "
        "tp devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=N)",
    )
    ap.add_argument(
        "--spec-k",
        type=int,
        default=None,
        help="self-speculative drafts per tick (None = config default)",
    )
    ap.add_argument("--temperature", type=float, default=0.7)
    ap.add_argument(
        "--deadline-ms",
        type=float,
        default=None,
        help="per-request deadline: late requests end with "
        "finish_reason='timeout' and the report gains the timed-out "
        "fraction (every request must still reach a terminal state)",
    )
    ap.add_argument(
        "--max-queue",
        type=int,
        default=None,
        help="bounded admission queue (gateway replies 429 beyond it)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slo-ttft-ms", type=float, default=2500.0)
    ap.add_argument("--slo-tpot-ms", type=float, default=1000.0)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI-sized run + report-shape/goodput/TTFT-bound assertions",
    )
    ap.add_argument(
        "--smoke-ttft-bound-ms",
        type=float,
        default=30000.0,
        help="p99 TTFT ceiling asserted under --smoke (post-warmup)",
    )
    ap.add_argument("--json", default=None, help="atomic report path")
    args = ap.parse_args()

    if args.smoke:
        args.requests = min(args.requests, 8)
        args.max_new = min(args.max_new, 4)
        # generous SLO: CPU CI measures the machinery, not the hardware
        args.slo_ttft_ms = max(args.slo_ttft_ms, 20000.0)
        args.slo_tpot_ms = max(args.slo_tpot_ms, 20000.0)

    cfg = smoke_config(args.arch)
    workload = build_workload(args, cfg.vocab)
    if args.inproc:
        records, wall, view = drive_inproc(args, workload)
        driver = "inproc"
    else:
        records, wall, view = drive_gateway(args, workload)
        driver = "gateway" if not args.url else "url"
    report = build_report(args, records, wall, view, driver)
    print_report(report)
    if args.json:
        write_json_atomic(args.json, report)
        print(f"wrote {args.json}")
    if args.smoke:
        check_report(report, smoke_ttft_bound_ms=args.smoke_ttft_bound_ms)
        print("smoke gate: OK")


if __name__ == "__main__":
    main()
