"""Paper Fig. 5: per-op energy vs matrix dimension M in BA-CAM.

Programming a CAM tile is amortized over M searches; per-op energy decays
toward the search-only bound."""

from repro.core.energy import energy_vs_m


def run(csv_rows):
    print("\n== Fig 5: BA-CAM per-op energy vs M (pJ) ==")
    e = energy_vs_m((1, 2, 4, 8, 16, 32, 64, 128, 256))
    for m, v in e.items():
        print(f"  M={m:4d}  {v*1e12:7.2f} pJ/op")
    ratio = e[1] / e[256]
    print(f"  amortization ratio E(1)/E(256) = {ratio:.2f}x")
    csv_rows.append(("fig5_amortization_ratio", ratio, "search+prog -> search"))
    return csv_rows
