"""Paper Table II (+ Figs 8 & 9): accelerator comparison on BERT-Large
single-query attention (n=1024, d_k=d_v=64, 16 heads, k=32, 1 GHz).

The CAMformer rows come from our system simulator (core/energy.py) built
from the paper's pipeline structure and component energies; baselines are
the published numbers.  Also times the JAX attention operator per mode on
this host (us_per_call column) to show the algorithmic compute reduction
CAMformer's sparsity delivers independent of the analog hardware.
"""

import time

import jax

from repro.core import AttentionSpec, attention
from repro.core.energy import area_mm2, attention_query_cost, table2_rows


def _time_op(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run(csv_rows):
    rows = table2_rows()
    print("\n== Table II: accelerator comparison (BERT-Large, n=1024) ==")
    print(f"{'accelerator':36s} {'bits':>9s} {'qry/ms':>8s} {'qry/mJ':>8s} "
          f"{'mm^2':>6s} {'W':>6s}")
    for name, r in rows.items():
        print(f"{name:36s} {r['bits']:>9s} {r['thr_qry_ms']:8.1f} "
              f"{r['eff_qry_mj']:8.0f} "
              f"{(r['area_mm2'] or 0):6.2f} {r['power_w']:6.2f}")
    ours = rows["CAMformer (ours, simulated)"]
    pub = rows["CAMformer (published)"]
    csv_rows.append(("table2_camformer_thr_qry_ms", ours["thr_qry_ms"],
                     f"published={pub['thr_qry_ms']}"))
    csv_rows.append(("table2_camformer_eff_qry_mj", ours["eff_qry_mj"],
                     f"published={pub['eff_qry_mj']}"))

    c = attention_query_cost()
    print("\n== Fig 8: energy breakdown (shares) ==")
    for k2, v in sorted(c["energy_shares"].items(), key=lambda kv: -kv[1]):
        print(f"  {k2:10s} {v*100:5.1f}%  ({c['energy_breakdown_nj'][k2]:.2f} nJ)")
    print(f"  total {c['energy_nj_per_query']:.1f} nJ/query "
          f"(+ DRAM {c['dram_nj_per_query']:.1f} nJ, reported separately)")
    print("\n== Fig 8 right: area (mm^2) ==")
    for k2, v in area_mm2(1).items():
        print(f"  {k2:10s} {v:6.3f}")
    print("\n== Fig 9: per-stage standalone throughput (qry/s) ==")
    for k2, v in c["stage_qps"].items():
        print(f"  {k2:18s} {v:,.0f}")

    # host-side operator timing: dense vs binary vs camformer (algorithmic)
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 1024, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 1024, 64))
    print("\n== JAX operator time on this host (single query, n=1024) ==")
    for mode in ("dense", "binary", "camformer"):
        spec = AttentionSpec(mode=mode, k_top=32)
        f = jax.jit(lambda q, k, v, s=spec: attention(q, k, v, s, causal=False))
        us = _time_op(f, q, k, v)
        print(f"  {mode:10s} {us:10.1f} us/call")
        csv_rows.append((f"attention_{mode}", us, "BERT-shape 1q x 1024"))
    return csv_rows
