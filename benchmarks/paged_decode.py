"""Serving-engine paged decode micro-benchmark.

Times one continuous-batching decode tick (fused paged CAM kernel, all
slots active) and the batched prefill, on the smoke config — fast enough
for CI (`run.py --smoke`), and a regression canary for the decode hot
path's dispatch overhead.
"""

import time

import jax

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, ServeEngine


def run(csv_rows, *, max_batch=4, max_new=8):
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_mode="camformer")
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=max_batch, max_len=64,
                      page_size=16)
    for i in range(max_batch):
        eng.submit(Request(prompt=[3 + i, 5, 8, 1], max_new_tokens=max_new,
                           rid=i))
    eng._admit()  # batched prefill + compile
    resident = eng.kv.used_pages
    eng.step()  # decode compile
    t0 = time.perf_counter()
    ticks = 0
    while eng.step():
        ticks += 1
    dt = (time.perf_counter() - t0) / max(ticks, 1) * 1e6
    print("\n== paged decode: one engine tick "
          f"(B={max_batch}, fused paged CAM kernel) ==")
    print(f"  {dt:9.1f} us/tick  ({dt / max_batch:8.1f} us/token)  "
          f"pool {resident}/{eng.kv.n_pages - 1} pages resident")
    csv_rows.append(("paged_decode_tick", dt, f"B={max_batch} us/tick"))
    return csv_rows
