"""Serving-engine paged decode micro-benchmark, swept over backends.

Times one continuous-batching decode tick (all slots active) and reports
decode ticks/s plus KV-cache bytes/token for each attention backend's
page layout — dense bf16 pages vs camformer bit-packed pages — as a
comparison table, then measures page-pool utilization with and without
copy-on-write prefix sharing (N requests with a common system prompt
prefill it once and alias its pages).  Fast enough for CI
(`run.py --smoke`), and a regression canary for the decode hot path's
dispatch overhead and the allocator's sharing behavior.

Standalone:

    PYTHONPATH=src:. python benchmarks/paged_decode.py \
        [--backend dense,camformer] [--max-batch 4] [--max-new 8]
"""

import argparse
import time

import jax

from repro.configs import smoke_config
from repro.core.backend import get_backend
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine


def _engine(backend, **kw):
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_backend=backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    return cfg, ServeEngine(md, cfg, params, **kw)


def bench_backend(backend: str, *, max_batch=4, max_new=8, page_size=16,
                  max_len=64):
    """One engine run on the smoke config; returns the metrics row."""
    cfg, eng = _engine(backend, max_batch=max_batch, max_len=max_len,
                       page_size=page_size)
    for i in range(max_batch):
        eng.submit(Request(prompt=[3 + i, 5, 8, 1],
                           sampling=SamplingParams(max_new=max_new), rid=i))
    eng.prefill(eng.schedule())  # batched prefill + compile
    resident = eng.kv.used_pages
    eng.step()  # decode compile
    t0 = time.perf_counter()
    ticks = 0
    while eng.step():
        ticks += 1
    dt = (time.perf_counter() - t0) / max(ticks, 1) * 1e6
    from repro.models.transformer import dtype_of

    bytes_tok = (get_backend(backend).cache_bytes_per_token(cfg, dtype_of(cfg))
                 * cfg.n_layers)
    return {
        "backend": backend,
        "us_per_tick": dt,
        "us_per_token": dt / max_batch,
        "ticks_per_s": 1e6 / dt,
        "kv_bytes_per_token": bytes_tok,
        "resident_pages": resident,
        "pool_pages": eng.kv.n_pages - 1,
    }


def bench_prefix_sharing(backend="dense", *, n_requests=6, prefix_len=32,
                         max_new=4, page_size=16, max_len=64):
    """Pool utilization for N requests sharing a common prompt prefix:
    COW sharing must make peak residency measurably smaller than N
    independent reservations."""
    system = list(range(7, 7 + prefix_len))
    prompts = [system + [50 + i, 51 + i] for i in range(n_requests)]
    peaks = {}
    for share in (False, True):
        _, eng = _engine(backend, max_batch=n_requests, max_len=max_len,
                         page_size=page_size, prefix_sharing=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p),
                               sampling=SamplingParams(max_new=max_new),
                               rid=i))
        eng.run()
        peaks[share] = eng.peak_pages
    pool = eng.kv.n_pages - 1
    return {
        "backend": backend,
        "n_requests": n_requests,
        "prefix_len": prefix_len,
        "peak_pages_independent": peaks[False],
        "peak_pages_shared": peaks[True],
        "pool_pages": pool,
        "util_independent": peaks[False] / pool,
        "util_shared": peaks[True] / pool,
    }


def run(csv_rows, *, max_batch=4, max_new=8, backends=("dense", "camformer")):
    rows = [bench_backend(b, max_batch=max_batch, max_new=max_new)
            for b in backends]
    print(f"\n== paged decode: one engine tick per backend "
          f"(B={max_batch}, shared paged serving path) ==")
    print(f"  {'backend':10s} {'us/tick':>10s} {'us/token':>10s} "
          f"{'ticks/s':>10s} {'KV B/token':>11s} {'pages':>9s}")
    for r in rows:
        print(f"  {r['backend']:10s} {r['us_per_tick']:10.1f} "
              f"{r['us_per_token']:10.1f} {r['ticks_per_s']:10.1f} "
              f"{r['kv_bytes_per_token']:11.0f} "
              f"{r['resident_pages']:>4d}/{r['pool_pages']}")
    if len(rows) > 1:
        base = rows[0]
        for r in rows[1:]:
            print(f"  {r['backend']} vs {base['backend']}: "
                  f"{base['us_per_tick'] / r['us_per_tick']:.2f}x tick speed, "
                  f"{base['kv_bytes_per_token'] / r['kv_bytes_per_token']:.2f}x"
                  f" KV bytes/token")
    for r in rows:
        csv_rows.append((f"paged_decode_tick_{r['backend']}",
                         r["us_per_tick"], f"B={max_batch} us/tick"))
        csv_rows.append((f"paged_kv_bytes_per_token_{r['backend']}",
                         r["kv_bytes_per_token"], "bytes/token all layers"))

    share = bench_prefix_sharing(backends[0])
    print(f"\n== COW prefix sharing ({share['backend']}): "
          f"{share['n_requests']} requests, {share['prefix_len']}-token "
          f"shared prefix ==")
    print(f"  peak pool residency: {share['peak_pages_independent']} pages "
          f"independent -> {share['peak_pages_shared']} shared "
          f"(of {share['pool_pages']}; utilization "
          f"{share['util_independent']:.0%} -> {share['util_shared']:.0%})")
    csv_rows.append((f"prefix_peak_pages_independent_{share['backend']}",
                     share["peak_pages_independent"],
                     f"N={share['n_requests']} prefix={share['prefix_len']}"))
    csv_rows.append((f"prefix_peak_pages_shared_{share['backend']}",
                     share["peak_pages_shared"],
                     f"N={share['n_requests']} prefix={share['prefix_len']}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense,camformer",
                    help="comma-separated backend sweep")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    run([], max_batch=args.max_batch, max_new=args.max_new,
        backends=tuple(args.backend.split(",")))


if __name__ == "__main__":
    main()
