"""Serving-engine paged decode micro-benchmark, swept over backends and
engine loops.

For each attention backend's page layout (dense bf16 pages vs camformer
bit-packed pages) this times full continuous-batching engine runs in BOTH
loop modes — synchronous (read every tick) and overlapped (dispatch-ahead
decode) — plus the XLA page-gather reference impl
(``paged_impl="gather"``), and reports decode ticks/s, per-request
p50/p99 inter-token latency, the host-idle fraction (host time blocked
on device readbacks), KV-cache bytes/token, KV bytes READ per decode
token by each impl (fused: live pages only; gather: the full table
extent) and the gather impl's peak logical-order scratch (fused: 0).
``--smoke`` asserts overlapped >= sync ticks/s for every backend plus,
for dense, the kernel-win gate: the deterministic bytes side (fused
reads <= gather reads, nonzero gather scratch) everywhere, and fused >=
gather ticks/s (with the overlap assertion's remeasure-retry) on TPU,
where the kernel runs compiled — off-TPU the tick ratio is recorded in
the JSON, not asserted.  A continuous-batching smoke then
measures a long-prompt request joining mid-stream: with ``prefill_slice``
its prompt prefills in page-sized chunks across ticks while resident
slots keep decoding.  Finally the copy-on-write prefix-sharing pool
report (page savings vs independent reservations).

Fast enough for CI (`run.py --smoke`, or standalone `--smoke --json`):
the JSON artifact records sync AND overlapped ticks/s per backend so the
overlap win accumulates in the perf trajectory.

Standalone:

With ``--spec-k K`` a self-speculative lane rides along: the same engine
run with K binary-stack drafts verified k+1 at a time per fused target
step vs the plain loop, reporting end-to-end tokens/s and the measured
draft acceptance rate; ``--smoke`` additionally gates spec >= plain
tokens/s on the binary target (its acceptance is structural — drafter
== target stack); dense/camformer smoke weights are random, so their
lanes have no draft signal to track and are record-only.

With ``--tp 1,2,...`` a tensor-parallel scaling lane rides along: the
same engine run with head-sharded page pools at each degree (one
shard_map-fused tick over a tp-axis device mesh — serving/sharded.py),
reporting ticks/s and per-device KV bytes read/token; on CPU set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (degrees beyond
the device count are recorded as skipped, never fail the run).

With ``--prefill-impl fused,gather`` a chunked-prefill impl lane rides
along: long prompts prefilled in page-sized chunks under each Sq>1
realization (the fused paged flash-prefill kernel vs the XLA
page-gather reference), reporting chunk ticks/s and the analytic KV
bytes read per prefill token; token streams are asserted identical, and
``--smoke`` gates fused bytes <= gather everywhere plus fused >= gather
chunk ticks/s on TPU (remeasure-retry).  The ``hybrid`` backend
(flash-scored fused prefill + CAM paged decode) is sweepable here and
in ``--backend`` like any other registry name.

Standalone:

    PYTHONPATH=src:. python benchmarks/paged_decode.py \
        [--backend dense,camformer,hybrid] [--max-batch 4] [--max-new 8] \
        [--spec-k 4] [--tp 1,2] [--prefill-impl fused,gather] \
        [--smoke] [--json BENCH.json]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.backend import get_backend
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, RequestState, SamplingParams, ServeEngine

MODES = ("sync", "overlap")


def _engine(backend, **kw):
    cfg = smoke_config("codeqwen1.5-7b").replace(attn_backend=backend)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    return cfg, ServeEngine(md, cfg, params, **kw)


def _timed_run(eng, prompts, max_new):
    """One drained engine run; returns (wall_s, ticks, blocked_s,
    per-request inter-token latency samples)."""
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=list(p),
                           sampling=SamplingParams(max_new=max_new)))
    ticks0, blocked0 = eng.ticks, eng.blocked_s
    arrivals = {}
    t0 = time.perf_counter()
    for out in eng.stream():
        arrivals.setdefault(out.rid, []).append(time.perf_counter())
    wall = time.perf_counter() - t0
    gaps = [b - a for ts in arrivals.values() for a, b in zip(ts, ts[1:])]
    return wall, eng.ticks - ticks0, eng.blocked_s - blocked0, gaps


def bench_backend(backend: str, *, max_batch=4, max_new=8, page_size=16,
                  max_len=64, repeats=2):
    """Engine runs on the smoke config — fused impl in BOTH loop modes
    plus the XLA page-gather reference impl (sync loop) — and the
    analytic decode I/O: KV bytes READ per token by each impl and the
    peak logical-order gather scratch the reference materializes."""
    prompts = [[3 + i, 5, 8, 1] for i in range(max_batch)]
    row = {"backend": backend}
    lanes = [(m, "fused") for m in MODES] + [("sync", "gather")]
    for mode, impl in lanes:
        cfg, eng = _engine(backend, max_batch=max_batch, max_len=max_len,
                           page_size=page_size, mode=mode, paged_impl=impl)
        _timed_run(eng, prompts, max_new)  # warm-up: compile both steps
        resident = None
        best = None
        for _ in range(repeats):
            wall, ticks, blocked, gaps = _timed_run(eng, prompts, max_new)
            resident = eng.peak_pages
            gaps = gaps or [0.0]  # max_new=1: no inter-token gaps exist
            m = {
                "ticks_per_s": ticks / max(wall, 1e-9),
                "us_per_tick": wall / max(ticks, 1) * 1e6,
                "p50_token_ms": float(np.percentile(gaps, 50)) * 1e3,
                "p99_token_ms": float(np.percentile(gaps, 99)) * 1e3,
                "host_idle_frac": blocked / max(wall, 1e-9),
            }
            if best is None or m["ticks_per_s"] > best["ticks_per_s"]:
                best = m
        row["gather" if impl == "gather" else mode] = best
        if impl == "fused":
            row["resident_pages"] = resident
            row["pool_pages"] = eng.kv.n_pages - 1
    from repro.models.transformer import dtype_of

    bk = get_backend(backend)
    dt = dtype_of(cfg)
    row["kv_bytes_per_token"] = (
        bk.cache_bytes_per_token(cfg, dt) * cfg.n_layers)
    # Decode-step I/O at the end-of-run kv extent (prompt + max_new):
    # fused walks live pages; gather dereferences the full table extent
    # and materializes the logical-order K/V scratch per layer x batch.
    io = bk.paged_io_stats(
        cfg, dt, kv_len=len(prompts[0]) + max_new, page_size=page_size,
        n_table_pages=eng.kv.max_pages_per_seq)
    row["kv_read_bytes_per_token"] = {
        "fused": io["fused_read_bytes"] * cfg.n_layers,
        "gather": io["gather_read_bytes"] * cfg.n_layers,
    }
    row["gather_scratch_peak_bytes"] = (
        io["gather_scratch_bytes"] * max_batch)  # one layer live at a time
    row["fused_vs_gather_ticks"] = (row["sync"]["ticks_per_s"]
                                    / max(row["gather"]["ticks_per_s"], 1e-9))
    if backend == "binary":
        # pre-PR5 regime for the record: the binary lane inherited the
        # dense gather + full-precision-softmax path wholesale, so its
        # numbers measured gather cost, not binarized scoring — the
        # "gather" lane above (now sign-match scoring over gathered
        # pages) is the closest surviving relative of that regime.
        row["note"] = ("binary decode now runs HAD sign-match scoring "
                       "via the fused paged flash-decode kernel; "
                       "pre-PR5 it aliased the dense gather path")
    row["us_per_token"] = row["overlap"]["us_per_tick"] / max_batch
    return row


def bench_spec(backend: str, *, spec_k, max_batch=4, max_new=8,
               page_size=16, max_len=96, repeats=2):
    """Self-speculative decoding lane: the SAME engine run with
    ``spec_k`` binary-stack drafts per tick (k+1 positions verified in
    one fused target step) vs the plain one-token loop, both sync +
    greedy.  Reports end-to-end generated tokens/s per lane, the
    tokens-per-tick amplification, and the measured draft acceptance
    rate from the engine counters."""
    prompts = [[3 + i, 5, 8, 1] for i in range(max_batch)]
    total = max_batch * max_new  # greedy, fixed max_new: exact count
    row = {"backend": backend, "spec_k": spec_k}
    for lane, k in (("plain", 0), ("spec", spec_k)):
        _, eng = _engine(backend, max_batch=max_batch, max_len=max_len,
                         page_size=page_size, mode="sync", spec_k=k)
        _timed_run(eng, prompts, max_new)  # warm-up: compile both steps
        best = None
        for _ in range(repeats):
            wall, ticks, _, _ = _timed_run(eng, prompts, max_new)
            m = {
                "tokens_per_s": total / max(wall, 1e-9),
                "ticks_per_s": ticks / max(wall, 1e-9),
                "tokens_per_tick": total / max(ticks, 1),
            }
            if best is None or m["tokens_per_s"] > best["tokens_per_s"]:
                best = m
        row[lane] = best
        if k:
            row["proposed"] = eng.spec_proposed
            row["accepted"] = eng.spec_accepted
            row["acceptance"] = eng.spec_acceptance
    row["spec_speedup"] = (row["spec"]["tokens_per_s"]
                           / max(row["plain"]["tokens_per_s"], 1e-9))
    return row


def bench_continuous(backend: str, *, page_size=16, max_len=96, max_new=12):
    """Continuous-batching smoke: a long-prompt request joins while a
    resident slot decodes; with ``prefill_slice=page_size`` its prompt
    prefills one page per tick and the resident slot must KEEP gaining a
    token every tick (no stop-the-world prefill)."""
    prefill_slice = page_size
    _, eng = _engine(backend, max_batch=2, max_len=max_len,
                     page_size=page_size, mode="sync",
                     prefill_slice=prefill_slice)
    a = Request(prompt=[5, 9, 2], sampling=SamplingParams(max_new=max_new))
    eng.submit(a)
    eng.step()
    prompt = list(range(100, 100 + 4 * page_size))
    joiner = Request(prompt=prompt, sampling=SamplingParams(max_new=2))
    eng.submit(joiner)
    chunk_ticks0 = eng.prefill_ticks
    interleaved = 0
    while joiner.state in (RequestState.QUEUED, RequestState.PREFILLING):
        before = len(a.tokens)
        eng.step()
        if len(a.tokens) > before:
            interleaved += 1
    eng.run()
    return {
        "backend": backend,
        # one prefill_slice-sized chunk per tick, computed from the
        # prompt actually submitted (not a hardcoded default-geometry 4)
        "prefill_ticks": -(-len(prompt) // prefill_slice),
        # the scheduler's measured chunk count for the joiner's span
        "measured_prefill_ticks": eng.prefill_ticks - chunk_ticks0,
        "decode_ticks_during_prefill": interleaved,
        "joiner_tokens": len(joiner.tokens),
        "resident_tokens": len(a.tokens),
    }


def bench_prefill_impl(backend: str, *, max_batch=4, page_size=16,
                       max_len=96, repeats=2,
                       impls=("fused", "gather")):
    """Fused-vs-gather Sq>1 chunk lane: long prompts prefilled in
    page-sized chunks (``prefill_slice=page_size``) under each
    ``--prefill-impl`` realization, reporting chunk ticks/s plus the
    analytic per-impl KV bytes READ per prefill token (the chunk reads
    the pools once, so per-token bytes divide by the chunk size —
    fused walks live pages, gather dereferences the table extent).
    Token streams are asserted identical across impls, so the lane
    measures realization cost, never output drift."""
    from repro.models.transformer import dtype_of

    prompt_len = 4 * page_size
    prompts = [list(range(100 + 64 * i, 100 + 64 * i + prompt_len))
               for i in range(max_batch)]
    row = {"backend": backend, "prompt_len": prompt_len,
           "prefill_slice": page_size, "lanes": {}}
    tokens = {}
    for impl in impls:
        cfg, eng = _engine(backend, max_batch=max_batch, max_len=max_len,
                           page_size=page_size, mode="sync",
                           prefill_slice=page_size, prefill_impl=impl)
        _timed_run(eng, prompts, 2)  # warm-up: compile chunk + decode
        best = None
        for _ in range(repeats):
            ticks0, toks0 = eng.prefill_ticks, eng.prefill_tokens
            wall, _, _, _ = _timed_run(eng, prompts, 2)
            chunk_ticks = eng.prefill_ticks - ticks0
            m = {
                "chunk_ticks": chunk_ticks,
                "prefill_tokens": eng.prefill_tokens - toks0,
                "chunk_ticks_per_s": chunk_ticks / max(wall, 1e-9),
            }
            if best is None or (m["chunk_ticks_per_s"]
                                > best["chunk_ticks_per_s"]):
                best = m
        io = get_backend(backend).paged_io_stats(
            cfg, dtype_of(cfg), kv_len=prompt_len, page_size=page_size,
            n_table_pages=eng.kv.max_pages_per_seq)
        best["kv_read_bytes_per_prefill_token"] = (
            io[f"prefill_{impl}_read_bytes"] * cfg.n_layers / page_size)
        row["lanes"][impl] = best
        tokens[impl] = sorted(
            (r.rid, tuple(r.tokens)) for r in eng.done)
    if "fused" in tokens and "gather" in tokens:
        assert tokens["fused"] == tokens["gather"], (
            f"{backend}: fused prefill chunks diverge from the gather "
            "oracle")
        row["fused_vs_gather_chunk_ticks"] = (
            row["lanes"]["fused"]["chunk_ticks_per_s"]
            / max(row["lanes"]["gather"]["chunk_ticks_per_s"], 1e-9))
    return row


def bench_prefix_sharing(backend="dense", *, n_requests=6, prefix_len=32,
                         max_new=4, page_size=16, max_len=64):
    """Pool utilization for N requests sharing a common prompt prefix:
    COW sharing must make peak residency measurably smaller than N
    independent reservations."""
    system = list(range(7, 7 + prefix_len))
    prompts = [system + [50 + i, 51 + i] for i in range(n_requests)]
    peaks = {}
    for share in (False, True):
        _, eng = _engine(backend, max_batch=n_requests, max_len=max_len,
                         page_size=page_size, prefix_sharing=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p),
                               sampling=SamplingParams(max_new=max_new),
                               rid=i))
        eng.run()
        peaks[share] = eng.peak_pages
    pool = eng.kv.n_pages - 1
    return {
        "backend": backend,
        "n_requests": n_requests,
        "prefix_len": prefix_len,
        "peak_pages_independent": peaks[False],
        "peak_pages_shared": peaks[True],
        "pool_pages": pool,
        "util_independent": peaks[False] / pool,
        "util_shared": peaks[True] / pool,
    }


def bench_tp(backend: str, *, tps, max_batch=4, max_new=8, page_size=16,
             max_len=64, repeats=2):
    """Tensor-parallel scaling lane: the same engine (sync loop, fused
    impl) run at each ``--tp`` degree over head-sharded page pools
    (serving/sharded.py).  Reports ticks/s plus the per-device KV bytes
    READ per decode token — the memory-partition win: every device walks
    the same live pages but only its 1/tp kv-head slice of each, so the
    per-device read traffic divides by tp while the token stream stays
    bit-identical (the identity matrix in tests/test_sharded.py)."""
    prompts = [[3 + i, 5, 8, 1] for i in range(max_batch)]
    from repro.models.transformer import dtype_of

    row = {"backend": backend, "lanes": {}}
    for tp in tps:
        if tp > jax.device_count():
            row["lanes"][str(tp)] = {
                "skipped": f"needs {tp} devices, have {jax.device_count()} "
                           "(set XLA_FLAGS="
                           f"--xla_force_host_platform_device_count={tp})"}
            continue
        cfg, eng = _engine(backend, max_batch=max_batch, max_len=max_len,
                           page_size=page_size, mode="sync", tp=tp)
        _timed_run(eng, prompts, max_new)  # warm-up: compile the step
        best = 0.0
        for _ in range(repeats):
            wall, ticks, _, _ = _timed_run(eng, prompts, max_new)
            best = max(best, ticks / max(wall, 1e-9))
        bk = get_backend(backend)
        io = bk.paged_io_stats(
            cfg, dtype_of(cfg), kv_len=len(prompts[0]) + max_new,
            page_size=page_size, n_table_pages=eng.kv.max_pages_per_seq)
        row["lanes"][str(tp)] = {
            "ticks_per_s": best,
            "kv_read_bytes_per_token_per_device":
                io["fused_read_bytes"] * cfg.n_layers / tp,
        }
    return row


def collect(backends, *, max_batch=4, max_new=8, spec_k=0, tps=(1,),
            prefill_impls=()):
    """One metrics payload covering every report — the single collection
    path shared by run() (run.py harness) and main() (standalone CLI)."""
    payload = {"backends": {}, "continuous": {}, "sharing": {},
               "speculative": {}, "tp": {}, "prefill": {}}
    for b in backends:
        payload["backends"][b] = bench_backend(
            b, max_batch=max_batch, max_new=max_new)
        payload["continuous"][b] = bench_continuous(b)
        if spec_k:
            payload["speculative"][b] = bench_spec(
                b, spec_k=spec_k, max_batch=max_batch, max_new=max_new)
        if tuple(tps) != (1,):
            payload["tp"][b] = bench_tp(
                b, tps=tps, max_batch=max_batch, max_new=max_new)
        if prefill_impls:
            payload["prefill"][b] = bench_prefill_impl(
                b, max_batch=max_batch, impls=tuple(prefill_impls))
    payload["sharing"][backends[0]] = bench_prefix_sharing(backends[0])
    return payload


def run(csv_rows, *, max_batch=4, max_new=8, backends=("dense", "camformer"),
        payload=None):
    payload = payload or collect(backends, max_batch=max_batch,
                                 max_new=max_new)
    rows = [payload["backends"][b] for b in backends]
    print(f"\n== paged decode: engine ticks per backend x loop mode x "
          f"impl (B={max_batch}, shared paged serving path) ==")
    print(f"  {'backend':10s} {'lane':12s} {'ticks/s':>9s} {'us/tick':>9s} "
          f"{'p50 ms':>8s} {'p99 ms':>8s} {'host idle':>9s} "
          f"{'rd B/tok':>9s}")
    for r in rows:
        for lane in MODES + ("gather",):
            m = r[lane]
            impl = "gather" if lane == "gather" else "fused"
            label = lane if lane == "gather" else f"{lane}/fused"
            print(f"  {r['backend']:10s} {label:12s} "
                  f"{m['ticks_per_s']:9.1f} "
                  f"{m['us_per_tick']:9.1f} {m['p50_token_ms']:8.2f} "
                  f"{m['p99_token_ms']:8.2f} {m['host_idle_frac']:8.0%} "
                  f"{r['kv_read_bytes_per_token'][impl]:9.0f}")
        speedup = (r["overlap"]["ticks_per_s"]
                   / max(r["sync"]["ticks_per_s"], 1e-9))
        print(f"  {r['backend']}: overlapped/sync = {speedup:.2f}x, "
              f"fused/gather = {r['fused_vs_gather_ticks']:.2f}x ticks/s, "
              f"gather scratch {r['gather_scratch_peak_bytes'] / 1024:.0f} "
              f"KiB -> fused 0")
    for r in rows:
        for mode in MODES:
            csv_rows.append(
                (f"paged_decode_ticks_per_s_{r['backend']}_{mode}",
                 r[mode]["ticks_per_s"], f"B={max_batch} {mode} loop"))
            csv_rows.append(
                (f"paged_decode_p99_token_ms_{r['backend']}_{mode}",
                 r[mode]["p99_token_ms"], f"{mode} p99 inter-token ms"))
        csv_rows.append((f"paged_decode_ticks_per_s_{r['backend']}_gather",
                         r["gather"]["ticks_per_s"],
                         "XLA page-gather reference impl, sync loop"))
        csv_rows.append((f"paged_decode_host_idle_{r['backend']}",
                         r["overlap"]["host_idle_frac"],
                         "overlapped-loop host idle fraction"))
        csv_rows.append((f"paged_kv_bytes_per_token_{r['backend']}",
                         r["kv_bytes_per_token"], "bytes/token all layers"))
        for impl in ("fused", "gather"):
            csv_rows.append(
                (f"paged_kv_read_bytes_per_token_{r['backend']}_{impl}",
                 r["kv_read_bytes_per_token"][impl],
                 "decode-step KV bytes read, all layers"))
        csv_rows.append(
            (f"paged_gather_scratch_peak_bytes_{r['backend']}",
             r["gather_scratch_peak_bytes"],
             "logical-order K/V scratch of the gather impl (fused: 0)"))

    cb = payload["continuous"][backends[0]]
    print(f"\n== continuous batching ({cb['backend']}): long prompt joins "
          f"mid-stream ==")
    print(f"  {cb['decode_ticks_during_prefill']} decode ticks interleaved "
          f"with ~{cb['prefill_ticks']} chunked-prefill ticks "
          f"(joiner generated {cb['joiner_tokens']} tokens after)")
    csv_rows.append((f"continuous_decode_ticks_during_prefill_{cb['backend']}",
                     cb["decode_ticks_during_prefill"],
                     "decode progress while a joiner prefills"))

    for b, sp in payload.get("speculative", {}).items():
        print(f"\n== self-speculative decoding ({b}): binary drafts, "
              f"k={sp['spec_k']}, fused k+1 verify ==")
        for lane in ("plain", "spec"):
            m = sp[lane]
            print(f"  {lane:6s} {m['tokens_per_s']:9.1f} tok/s "
                  f"{m['ticks_per_s']:9.1f} ticks/s "
                  f"{m['tokens_per_tick']:6.2f} tok/tick")
        print(f"  acceptance {sp['accepted']}/{sp['proposed']} "
              f"({sp['acceptance']:.0%}), end-to-end "
              f"{sp['spec_speedup']:.2f}x tokens/s")
        csv_rows.append((f"spec_decode_tokens_per_s_{b}_plain",
                         sp["plain"]["tokens_per_s"], "spec_k=0 baseline"))
        csv_rows.append((f"spec_decode_tokens_per_s_{b}_spec",
                         sp["spec"]["tokens_per_s"],
                         f"spec_k={sp['spec_k']} binary drafts"))
        csv_rows.append((f"spec_decode_acceptance_{b}",
                         sp["acceptance"],
                         f"drafts accepted, k={sp['spec_k']} greedy"))
        csv_rows.append((f"spec_decode_tokens_per_tick_{b}",
                         sp["spec"]["tokens_per_tick"],
                         "multi-token tick amplification"))

    for b, r in payload.get("tp", {}).items():
        print(f"\n== tensor-parallel sharded serving ({b}): head-sharded "
              f"page pools, one shard_map tick ==")
        print(f"  {'tp':>4s} {'ticks/s':>9s} {'KV rd B/tok/dev':>16s}")
        for tp, m in sorted(r["lanes"].items(), key=lambda kv: int(kv[0])):
            if "skipped" in m:
                print(f"  {tp:>4s} skipped: {m['skipped']}")
                continue
            print(f"  {tp:>4s} {m['ticks_per_s']:9.1f} "
                  f"{m['kv_read_bytes_per_token_per_device']:16.0f}")
            csv_rows.append(
                (f"paged_decode_ticks_per_s_{b}_tp{tp}",
                 m["ticks_per_s"], f"tp={tp} head-sharded, sync loop"))
            csv_rows.append(
                (f"paged_kv_read_bytes_per_token_per_device_{b}_tp{tp}",
                 m["kv_read_bytes_per_token_per_device"],
                 f"fused decode reads / device at tp={tp}"))

    for b, r in payload.get("prefill", {}).items():
        print(f"\n== chunked-prefill impl sweep ({b}): "
              f"{r['prompt_len']}-token prompts, "
              f"{r['prefill_slice']}-token chunks ==")
        print(f"  {'impl':8s} {'chunk ticks/s':>14s} "
              f"{'KV rd B/prefill tok':>20s}")
        for impl, m in r["lanes"].items():
            print(f"  {impl:8s} {m['chunk_ticks_per_s']:14.1f} "
                  f"{m['kv_read_bytes_per_prefill_token']:20.0f}")
            csv_rows.append(
                (f"paged_prefill_chunk_ticks_per_s_{b}_{impl}",
                 m["chunk_ticks_per_s"],
                 f"{r['prefill_slice']}-token chunks, sync loop"))
            csv_rows.append(
                (f"paged_kv_read_bytes_per_prefill_token_{b}_{impl}",
                 m["kv_read_bytes_per_prefill_token"],
                 "prefill-chunk KV bytes read / prompt token, all layers"))
        if "fused_vs_gather_chunk_ticks" in r:
            print(f"  {b}: fused/gather = "
                  f"{r['fused_vs_gather_chunk_ticks']:.2f}x chunk ticks/s "
                  f"(token streams asserted identical)")
            csv_rows.append(
                (f"paged_prefill_fused_vs_gather_chunk_ticks_{b}",
                 r["fused_vs_gather_chunk_ticks"],
                 "Sq>1 fused flash chunks vs the gather oracle"))

    share = payload["sharing"][backends[0]]
    print(f"\n== COW prefix sharing ({share['backend']}): "
          f"{share['n_requests']} requests, {share['prefix_len']}-token "
          f"shared prefix ==")
    print(f"  peak pool residency: {share['peak_pages_independent']} pages "
          f"independent -> {share['peak_pages_shared']} shared "
          f"(of {share['pool_pages']}; utilization "
          f"{share['util_independent']:.0%} -> {share['util_shared']:.0%})")
    csv_rows.append((f"prefix_peak_pages_independent_{share['backend']}",
                     share["peak_pages_independent"],
                     f"N={share['n_requests']} prefix={share['prefix_len']}"))
    csv_rows.append((f"prefix_peak_pages_shared_{share['backend']}",
                     share["peak_pages_shared"],
                     f"N={share['n_requests']} prefix={share['prefix_len']}"))
    return csv_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="dense,camformer",
                    help="comma-separated backend sweep")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--spec-k", type=int, default=0,
                    help="also bench self-speculative decoding with this "
                         "many binary-stack drafts per tick (0 = skip)")
    ap.add_argument("--tp", default="1",
                    help="comma-separated tensor-parallel sweep (e.g. "
                         "'1,2'): per-degree ticks/s + per-device KV "
                         "bytes read/token over head-sharded page pools "
                         "(degrees beyond the device count are recorded "
                         "as skipped; '1' alone = no sweep)")
    ap.add_argument("--prefill-impl", default="",
                    help="comma-separated Sq>1 chunk realization sweep "
                         "(e.g. 'fused,gather'): per-impl chunked-prefill "
                         "ticks/s + analytic KV bytes read per prefill "
                         "token, token streams asserted identical "
                         "(empty = skip the lane)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; asserts overlapped >= sync ticks/s "
                         "and (with --spec-k) spec >= plain tokens/s")
    ap.add_argument("--json", default=None,
                    help="write the full metrics payload to this file")
    args = ap.parse_args()
    backends = tuple(args.backend.split(","))
    max_new = 6 if args.smoke else args.max_new
    tps = tuple(int(x) for x in args.tp.split(","))
    prefill_impls = tuple(x for x in args.prefill_impl.split(",") if x)

    payload = collect(backends, max_batch=args.max_batch, max_new=max_new,
                      spec_k=args.spec_k, tps=tps,
                      prefill_impls=prefill_impls)
    if args.smoke and args.spec_k and "binary" not in payload["speculative"]:
        # the gated lane: binary drafts == the binary target by
        # construction, so its acceptance (and the multi-token win) is
        # structural, not a property of the smoke weights
        payload["speculative"]["binary"] = bench_spec(
            "binary", spec_k=args.spec_k, max_batch=args.max_batch,
            max_new=max_new)
    run([], max_batch=args.max_batch, max_new=max_new, backends=backends,
        payload=payload)  # the one shared reporting path
    if args.smoke and args.spec_k:
        # The multi-token-tick win gate: with greedy drafts the accepted
        # prefix amortizes the fixed per-tick host+dispatch cost, so
        # end-to-end tokens/s must not regress vs the plain loop where
        # acceptance is STRUCTURAL — the binary target, whose drafter is
        # the very same stack (acceptance 1.0 by construction).  The
        # dense/camformer smoke targets decode from RANDOM weights,
        # where binarized drafting has no real-model signal to track
        # (trained CAMformer checkpoints are the ~lossless regime the
        # paper measures), so their lanes are recorded in the JSON for
        # the trajectory, not asserted.
        for b, sp in payload["speculative"].items():
            if b != "binary":
                continue
            if sp["spec_speedup"] >= 1.0:
                continue
            # wall-clock race on a noisy runner: re-measure once with
            # more repeats before declaring the multi-token win regressed
            sp2 = bench_spec(b, spec_k=args.spec_k,
                             max_batch=args.max_batch, max_new=max_new,
                             repeats=4)
            print(f"{b}: remeasured plain "
                  f"{sp2['plain']['tokens_per_s']:.1f} | spec "
                  f"{sp2['spec']['tokens_per_s']:.1f} tok/s "
                  f"({sp2['acceptance']:.0%} accepted)")
            assert sp2["spec_speedup"] >= 1.0, (
                f"{b}: speculative decode slower than the plain loop "
                f"(reproduced; acceptance {sp2['acceptance']:.0%})")
    if args.json:
        from repro.utils import write_json_atomic

        # atomic (write-temp + rename): a timed-out CI lane can never
        # upload a truncated BENCH_*.json artifact
        write_json_atomic(args.json, payload)
        print(f"wrote {args.json}")
    if args.smoke:
        for b, r in payload["backends"].items():
            if r["overlap"]["ticks_per_s"] >= r["sync"]["ticks_per_s"]:
                continue
            # wall-clock race on a noisy runner: re-measure once with
            # more repeats before declaring the overlap win regressed
            r2 = bench_backend(b, max_batch=args.max_batch,
                               max_new=max_new, repeats=4)
            print(f"{b}: remeasured sync {r2['sync']['ticks_per_s']:.1f} "
                  f"| overlapped {r2['overlap']['ticks_per_s']:.1f} ticks/s")
            assert (r2["overlap"]["ticks_per_s"]
                    >= r2["sync"]["ticks_per_s"]), (
                f"{b}: overlapped loop slower than sync (reproduced)")
        # the kernel win gate (BENCH_serving_dense.json).  The wall-clock
        # half — fused ticks/s >= gather ticks/s, with the same
        # remeasure-retry as the overlap>=sync assertion — is only
        # meaningful where the Pallas kernel actually runs compiled
        # (TPU): off-TPU the fused lane is the jnp page walk, whose
        # per-tick cost is noise-level-equal to the gather attend at
        # smoke sizes, so the ratio is recorded in the JSON but not
        # asserted.  The deterministic half of the win — decode KV
        # bytes read proportional to live pages, zero gather scratch —
        # is asserted everywhere.
        r = payload["backends"].get("dense")
        if r is not None:
            rd = r["kv_read_bytes_per_token"]
            assert rd["fused"] <= rd["gather"], rd
            assert r["gather_scratch_peak_bytes"] > 0, r
            on_tpu = jax.default_backend() == "tpu"
            if (on_tpu and r["sync"]["ticks_per_s"]
                    < r["gather"]["ticks_per_s"]):
                r2 = bench_backend("dense", max_batch=args.max_batch,
                                   max_new=max_new, repeats=4)
                print(f"dense: remeasured fused "
                      f"{r2['sync']['ticks_per_s']:.1f} | gather "
                      f"{r2['gather']['ticks_per_s']:.1f} ticks/s")
                assert (r2["sync"]["ticks_per_s"]
                        >= r2["gather"]["ticks_per_s"]), (
                    "dense: fused paged flash-decode slower than the "
                    "gather reference (reproduced)")
        # the prefill-chunk kernel win gate, same split as the decode
        # one: the deterministic half — fused chunks read only live KV
        # rows while gather dereferences the full table extent — is
        # asserted for every swept backend everywhere; the wall-clock
        # half (fused chunk ticks/s >= gather, remeasure-retry) only
        # where the Pallas kernel runs compiled (TPU).  For camformer
        # both prefill columns are the gather numbers (no fused Sq>1
        # CAM kernel yet), so <= holds trivially there.
        on_tpu = jax.default_backend() == "tpu"
        for b, r in payload.get("prefill", {}).items():
            lanes = r["lanes"]
            if "fused" not in lanes or "gather" not in lanes:
                continue
            assert (lanes["fused"]["kv_read_bytes_per_prefill_token"]
                    <= lanes["gather"]["kv_read_bytes_per_prefill_token"]), (
                f"{b}: fused prefill chunks read more KV bytes than the "
                f"gather reference: {lanes}")
            if on_tpu and r["fused_vs_gather_chunk_ticks"] < 1.0:
                # wall-clock race on a noisy runner: re-measure with more
                # repeats before declaring the chunk-kernel win regressed
                r2 = bench_prefill_impl(b, max_batch=args.max_batch,
                                        repeats=4)
                l2 = r2["lanes"]
                print(f"{b}: remeasured fused "
                      f"{l2['fused']['chunk_ticks_per_s']:.1f} | gather "
                      f"{l2['gather']['chunk_ticks_per_s']:.1f} "
                      f"chunk ticks/s")
                assert r2["fused_vs_gather_chunk_ticks"] >= 1.0, (
                    f"{b}: fused Sq>1 flash-prefill chunks slower than "
                    "the gather reference (reproduced)")


if __name__ == "__main__":
    main()
