"""Paper Tables III/IV: two-stage vs single-stage top-k accuracy.

We cannot run DeiT/ImageNet or BERT/GLUE offline, so this benchmark
validates the paper's *mechanism* claims with measurable proxies:

  1. recall@k of two-stage (top-2-per-16 -> top-32) vs exact top-32 on
     (a) real attention-score distributions from a small trained LM and
     (b) synthetic correlated scores; the paper's Hoeffding bound is
     checked against the empirical drop rate.
  2. an end-to-end quality ladder on a small LM trained here:
     dense -> HAD-binary (full softmax) -> binary+single-stage top-32 ->
     binary+two-stage top-32 (the paper's configuration).  Tables III/IV
     say the LAST TWO should be nearly identical; that gap is the
     reproduced number.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import SHAPES
from repro.core import (hoeffding_drop_bound, single_stage_topk, topk_recall,
                        two_stage_topk)
from repro.launch.mesh import make_mesh_for
from repro.models import get_model_def
from repro.train.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainerConfig


def recall_table(csv_rows):
    print("\n== recall@32 of two-stage top-k (group 16) vs exact ==")
    rng = np.random.default_rng(0)
    for name, scores in [
        ("gaussian", rng.normal(size=(256, 1024))),
        ("heavy-tail", rng.standard_t(3, size=(256, 1024))),
        ("correlated", rng.normal(size=(256, 1)) + 0.3 * rng.normal(size=(256, 1024))),
    ]:
        s = jnp.asarray(scores.astype(np.float32))
        for s1 in (1, 2, 4, 8):
            tv, ti = two_stage_topk(s, k=32, group_size=16, stage1_k=s1)
            sv, si = single_stage_topk(s, 32)
            rec = float(topk_recall(ti, si).mean())
            mass = float((tv.sum(-1) / sv.sum(-1)).mean())
            print(f"  {name:12s} stage1_k={s1}  recall@32={rec:.4f} "
                  f"score-mass={mass:.4f}")
            if s1 == 2:
                csv_rows.append((f"recall32_{name}_k2", rec, "paper k=2 row"))
    return csv_rows


def hoeffding_check(csv_rows):
    print("\n== Hoeffding drop bound vs empirical (binary scores, d=64) ==")
    key = jax.random.PRNGKey(0)
    d, n, k = 64, 1024, 32
    base = jax.random.normal(key, (128, 1, d))
    q = jnp.sign(base + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (128, 1, d)))
    kk = jnp.sign(base + 0.8 * jax.random.normal(jax.random.PRNGKey(2), (128, n, d)))
    scores = jnp.einsum("bqd,bnd->bqn", q, kk)[:, 0]
    tv, ti = two_stage_topk(scores, k=k, group_size=16, stage1_k=2)
    sv, si = single_stage_topk(scores, k)
    emp_drop = 1.0 - float(topk_recall(ti, si).mean())
    # empirical margin at the k-th score (normalized per paper's delta)
    margin = float((sv[:, k - 1] - jnp.sort(scores, -1)[:, -(k + 1)]).mean()) / (2 * d)
    bound = hoeffding_drop_bound(d, max(margin, 1e-3), k, n)
    print(f"  empirical drop={emp_drop:.4f}  margin={margin:.4f} "
          f"Hoeffding bound={bound:.4f}  (bound >= empirical: {bound >= emp_drop})")
    csv_rows.append(("hoeffding_empirical_drop", emp_drop, f"bound={bound:.3f}"))
    return csv_rows


def quality_ladder(csv_rows, steps=60):
    print("\n== end-to-end quality ladder (small LM trained here) ==")
    SHAPES["bench"] = dict(seq_len=128, global_batch=8, kind="train")
    cfg = smoke_config("codeqwen1.5-7b", d_model=128, n_layers=2, n_heads=4,
                       n_kv_heads=4, head_dim=64, vocab=512, k_top=32,
                       group_size=16)
    md = get_model_def(cfg)
    mesh = make_mesh_for(1, 1)
    data = SyntheticLMData(cfg, "bench", mesh, seed=0)
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=10**9, log_every=steps,
                         ckpt_dir="/tmp/bench_ckpt_ladder", peak_lr=2e-3,
                         warmup=5)
    import shutil
    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    trainer = Trainer(md, cfg, mesh, data, tcfg)
    state = trainer.run()
    params = state["params"]

    eval_batches = [data.batch(10_000 + i) for i in range(4)]

    def eval_ce(cfg_eval):
        md_e = get_model_def(cfg_eval)
        tot = 0.0
        for b in eval_batches:
            loss, aux = md_e.loss(params, b, cfg_eval)
            tot += float(aux["ce"])
        return tot / len(eval_batches)

    ladder = {
        "dense": cfg,
        "binary (HAD, full softmax)": cfg.replace(attn_backend="binary"),
        "binary + single-stage top-32": cfg.replace(
            attn_backend="camformer", stage1_k=16),  # stage1_k=group => exact
        "binary + two-stage top-2/16 (paper)": cfg.replace(
            attn_backend="camformer", stage1_k=2),
    }
    results = {name: eval_ce(c) for name, c in ladder.items()}
    base = results["dense"]
    for name, ce in results.items():
        print(f"  {name:38s} CE={ce:.4f}  (delta vs dense {ce-base:+.4f})")
    two_vs_one = (results["binary + two-stage top-2/16 (paper)"]
                  - results["binary + single-stage top-32"])
    print(f"  => two-stage vs single-stage gap: {two_vs_one:+.4f} "
          f"(paper: <= 0.4% metric delta)")
    csv_rows.append(("ladder_two_vs_single_stage_ce_gap", two_vs_one,
                     "paper claims ~0"))
    csv_rows.append(("ladder_binary_vs_dense_ce_gap",
                     results["binary (HAD, full softmax)"] - base,
                     "undistilled; HAD closes this"))
    return csv_rows


def run(csv_rows):
    csv_rows = recall_table(csv_rows)
    csv_rows = hoeffding_check(csv_rows)
    csv_rows = quality_ladder(csv_rows)
    return csv_rows
