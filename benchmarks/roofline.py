"""Roofline report: assembles the (arch x shape x mesh) table from the
dry-run artifacts in results/dryrun/ (launch/dryrun.py)."""

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(mesh_tag="pod"):
    rows = []
    pattern = (f"*_{mesh_tag}.json" if mesh_tag != "hc"
               else "*_hc_*.json")
    for f in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        rows.append(json.load(open(f)))
    return rows


def run(csv_rows):
    for tag, label in (("pod", "single-pod 16x16"),
                       ("multipod", "multi-pod 2x16x16"),
                       ("hc", "HILLCLIMBED variants (EXPERIMENTS §Perf: "
                              "dp profile / distributed CAM search)")):
        rows = load_cells(tag)
        if not rows:
            print(f"\n== roofline table ({label}): no dry-run artifacts — "
                  f"run `python -m repro.launch.dryrun --all"
                  f"{' --multi-pod' if tag == 'multipod' else ''}` ==")
            continue
        print(f"\n== roofline table ({label}; terms s/step; "
              f"197TF bf16, 819GB/s HBM, 50GB/s link) ==")
        print(f"{'arch':24s} {'shape':12s} {'mode':10s} {'comp_s':>9s} "
              f"{'mem_s':>9s} {'coll_s':>9s} {'dominant':>10s} {'roof%':>6s} "
              f"{'useful%':>8s} {'GB/dev':>7s}")
        worst = None
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            roof = r["roofline"]
            gb = r["memory"]["per_device_total"] / 2**30
            mode = (r.get("backend") or r.get("attn_mode", "?")) + (
                "+" + r["tag"] if r.get("tag") else "")
            print(f"{r['arch']:24s} {r['shape']:12s} {mode:16s} "
                  f"{roof['compute_s']:9.2e} {roof['memory_s']:9.2e} "
                  f"{roof['collective_s']:9.2e} {roof['dominant']:>10s} "
                  f"{roof['roofline_fraction']*100:6.1f} "
                  f"{roof['useful_flops_ratio']*100:8.1f} {gb:7.1f}")
            if r["kind"] == "train":
                suffix = ("_" + r["tag"]) if r.get("tag") else ""
                csv_rows.append((f"roofline_{tag}_{r['arch']}_{r['shape']}"
                                 f"{suffix}",
                                 roof["roofline_fraction"],
                                 roof["dominant"] + "-bound"))
        n_fit = sum(1 for r in rows
                    if r["memory"]["per_device_total"] < 16 * 2**30)
        print(f"  cells fitting 16 GB HBM/device: {n_fit}/{len(rows)}")
    return csv_rows
