"""Serve a small model with batched requests through the continuous-
batching engine, comparing dense vs CAMformer attention caches.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving.engine import Request, ServeEngine


LAYOUTS = {
    "dense": "dense bf16 K/V pages",
    "binary": "dense bf16 K/V pages (HAD-binarized scoring)",
    "camformer": "packed binary K pages (6.25% of bf16) + top-32 sparse V",
}


def run(backend: str, layer_backends=None):
    cfg = smoke_config("codeqwen1.5-7b").replace(
        attn_backend=backend, layer_backends=layer_backends)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(md, cfg, params, max_batch=4, max_len=96)
    prompts = [[7, 3, 9, 1], [5, 5, 2], [8, 1, 4, 4, 6], [2, 9],
               [1, 2, 3, 4, 5], [6, 6, 6]]
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=p, max_new_tokens=12, rid=i))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done)
    label = ",".join(layer_backends) if layer_backends else backend
    layout = (" / ".join(LAYOUTS.get(b, b)
                         for b in dict.fromkeys(cfg.backend_names))
              if layer_backends else LAYOUTS.get(backend, backend))
    print(f"[{label:15s}] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); page layout: {layout}")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"   req {r.rid}: {r.prompt} -> {r.tokens}")


if __name__ == "__main__":
    run("dense")
    run("camformer")
    # per-layer policy: both page layouts live in the same pool
    run("dense", layer_backends=("dense", "camformer"))
