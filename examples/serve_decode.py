"""Serve a small model through the continuous-batching engine: streamed
outputs, per-request sampling, and copy-on-write prefix sharing, compared
across dense / CAMformer attention page layouts.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax

from repro.configs import smoke_config
from repro.models import get_model_def
from repro.models.module import init_params
from repro.serving import Request, SamplingParams, ServeEngine


LAYOUTS = {
    "dense": "dense bf16 K/V pages",
    "binary": "dense bf16 K/V pages (HAD-binarized scoring)",
    "camformer": "packed binary K pages (6.25% of bf16) + top-32 sparse V",
}


def build(backend, layer_backends=None, **kw):
    cfg = smoke_config("codeqwen1.5-7b").replace(
        attn_backend=backend, layer_backends=layer_backends)
    md = get_model_def(cfg)
    params = init_params(md.specs(cfg), jax.random.PRNGKey(0))
    return cfg, ServeEngine(md, cfg, params, max_batch=4, max_len=96, **kw)


def run(backend: str, layer_backends=None):
    cfg, eng = build(backend, layer_backends)
    prompts = [[7, 3, 9, 1], [5, 5, 2], [8, 1, 4, 4, 6], [2, 9],
               [1, 2, 3, 4, 5], [6, 6, 6]]
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=p, sampling=SamplingParams(max_new=12),
                           rid=i))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done)
    label = ",".join(layer_backends) if layer_backends else backend
    layout = (" / ".join(LAYOUTS.get(b, b)
                         for b in dict.fromkeys(cfg.backend_names))
              if layer_backends else LAYOUTS.get(backend, backend))
    print(f"[{label:15s}] {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); page layout: {layout}")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"   req {r.rid}: {r.prompt} -> {r.tokens}")


def run_streaming():
    """Tokens surface as they are generated — iterator or callback."""
    _, eng = build("camformer")
    reqs = [Request(prompt=[7, 3, 9, 1],
                    sampling=SamplingParams(max_new=8)),  # greedy
            Request(prompt=[5, 5, 2],
                    sampling=SamplingParams(temperature=0.8, top_k=40,
                                            top_p=0.95, max_new=8))]
    print("[streaming      ] ", end="")
    for out in eng.stream(*reqs):
        print(f"r{out.rid}:{out.token}", end=" ")
    print()


def run_prefix_sharing():
    """A shared 24-token system prompt is prefilled ONCE: later requests
    alias its full pages (refcount++) and COW-fork the boundary page."""
    system = list(range(100, 124))
    prompts = [system + [i, 2 * i + 1] for i in range(1, 7)]
    stats = {}
    for share in (False, True):
        _, eng = build("camformer", page_size=16, prefix_sharing=share)
        for i, p in enumerate(prompts):
            eng.submit(Request(prompt=list(p),
                               sampling=SamplingParams(max_new=8), rid=i))
        eng.run()
        stats[share] = eng.peak_pages
    print(f"[prefix sharing ] 6 requests x 26-token prompts (24 shared): "
          f"peak {stats[False]} pages independent vs {stats[True]} shared")


if __name__ == "__main__":
    run("dense")
    run("camformer")
    # per-layer policy: both page layouts live in the same pool
    run("dense", layer_backends=("dense", "camformer"))
    run_streaming()
    run_prefix_sharing()
