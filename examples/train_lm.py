"""End-to-end training driver: train a ~100M-parameter LM with the full
substrate (sharded data pipeline, AdamW, async checkpointing, fault-
tolerant trainer).

On a real slice:
    PYTHONPATH=src python examples/train_lm.py --steps 300
trains the ~125M default config for a few hundred steps on all available
devices.  On this CPU container use --tiny (a ~2M-param model; the same
code path end to end):
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 60
"""

import argparse

import jax

from repro.configs.base import ModelConfig, SHAPES
from repro.launch.mesh import make_mesh_for
from repro.models import get_model_def
from repro.train.data import SyntheticLMData
from repro.train.trainer import Trainer, TrainerConfig


def lm_100m():
    return ModelConfig(
        name="lm-125m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, head_dim=64, d_ff=3072, vocab=8192,
        dtype="float32",
    )


def lm_tiny():
    return ModelConfig(
        name="lm-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256, vocab=512,
        dtype="float32", k_top=8, group_size=4,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    from repro.launch.cli import add_backend_args, apply_backend_args
    add_backend_args(ap, choices=["dense", "binary", "camformer"])
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = apply_backend_args(lm_tiny() if args.tiny else lm_100m(), args)
    seq = args.seq or (128 if args.tiny else 1024)
    batch = args.batch or (8 if args.tiny else 64)
    SHAPES["e2e"] = dict(seq_len=seq, global_batch=batch, kind="train")

    mesh = make_mesh_for(len(jax.devices()), 1)
    md = get_model_def(cfg)
    from repro.models.module import count_params

    print(f"model: {cfg.name}  params={count_params(md.specs(cfg)):,}  "
          f"attn={cfg.uniform_backend or ','.join(cfg.backend_names)}  "
          f"seq={seq} batch={batch}")
    data = SyntheticLMData(cfg, "e2e", mesh)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=max(10, args.steps // 4),
                         log_every=max(1, args.steps // 15),
                         ckpt_dir=args.ckpt_dir, peak_lr=1e-3,
                         warmup=args.steps // 10)
    trainer = Trainer(md, cfg, mesh, data, tcfg)
    trainer.run()
    print(f"{'step':>6s} {'loss':>9s} {'lr':>9s} {'gnorm':>8s} {'s/step':>7s}")
    for row in trainer.metrics_log:
        print(f"{row['step']:6d} {row['loss']:9.4f} {row['lr']:9.2e} "
              f"{row['grad_norm']:8.2f} {row['step_time_s']:7.3f}")
    for ev in trainer.events:
        print("event:", ev)
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no improvement'})")


if __name__ == "__main__":
    main()
