"""Hamming Attention Distillation (HAD) — the paper's accuracy foundation.

Trains a small dense teacher, then distills a binarized-Q/K student
(straight-through sign) by matching attention task loss; shows the binary
student recovering toward teacher quality, and that switching the DISTILLED
student from single-stage to the paper's two-stage top-k costs ~nothing
(the Tables III/IV mechanism).

    PYTHONPATH=src python examples/had_distill.py
"""

import jax

from repro.configs import smoke_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_mesh_for
from repro.models import get_model_def
from repro.models.module import init_params
from repro.train.data import SyntheticLMData
from repro.train.optimizer import adamw, constant_schedule

SHAPES["had"] = dict(seq_len=128, global_batch=8, kind="train")


def train(cfg, params, data, steps, lr=1e-3, start_step=0):
    md = get_model_def(cfg)
    opt = adamw(constant_schedule(lr))
    state = opt.init(params)

    @jax.jit
    def step_fn(params, state, batch):
        (loss, aux), g = jax.value_and_grad(md.loss, has_aux=True)(
            params, batch, cfg)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    loss = None
    for i in range(steps):
        params, state, loss = step_fn(params, state, data.batch(start_step + i))
    return params, float(loss)


def eval_ce(cfg, params, data, n=4):
    md = get_model_def(cfg)
    tot = 0.0
    for i in range(n):
        _, aux = md.loss(params, data.batch(5000 + i), cfg)
        tot += float(aux["ce"])
    return tot / n


def main():
    mesh = make_mesh_for(1, 1)
    base = smoke_config("codeqwen1.5-7b", d_model=128, n_layers=2, n_heads=4,
                        n_kv_heads=4, head_dim=32, vocab=512, k_top=16,
                        group_size=8)
    data = SyntheticLMData(base, "had", mesh, seed=0)
    md = get_model_def(base)
    params = init_params(md.specs(base), jax.random.PRNGKey(0))

    print("1) train dense teacher (80 steps)...")
    params, _ = train(base, params, data, steps=80)
    ce_teacher = eval_ce(base, params, data)

    bin_cfg = base.replace(attn_backend="binary")
    ce_binary_0 = eval_ce(bin_cfg, params, data)

    print("2) HAD fine-tune: binarized Q/K student w/ straight-through sign "
          "(40 steps)...")
    student = params
    student, _ = train(bin_cfg, student, data, steps=40, lr=5e-4,
                       start_step=80)
    ce_binary_had = eval_ce(bin_cfg, student, data)

    cam1 = bin_cfg.replace(attn_backend="camformer", stage1_k=8)  # single-stage
    cam2 = bin_cfg.replace(attn_backend="camformer", stage1_k=2)  # paper
    ce_cam1 = eval_ce(cam1, student, data)
    ce_cam2 = eval_ce(cam2, student, data)

    print(f"\n{'config':44s} {'eval CE':>8s}")
    print(f"{'dense teacher':44s} {ce_teacher:8.4f}")
    print(f"{'binary Q/K, zero-shot (no distillation)':44s} {ce_binary_0:8.4f}")
    print(f"{'binary Q/K after HAD fine-tune':44s} {ce_binary_had:8.4f}")
    print(f"{'HAD student + single-stage top-k':44s} {ce_cam1:8.4f}")
    print(f"{'HAD student + two-stage top-2/grp (paper)':44s} {ce_cam2:8.4f}")
    print(f"\nHAD recovers {100*(ce_binary_0-ce_binary_had)/max(ce_binary_0-ce_teacher,1e-9):.0f}% "
          f"of the binarization gap; two-stage costs "
          f"{ce_cam2-ce_cam1:+.4f} CE vs single-stage (paper: ~0).")


if __name__ == "__main__":
    main()
