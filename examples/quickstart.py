"""Quickstart: CAMformer attention as a drop-in JAX operator.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (AttentionSpec, attention, dense_reference,
                        single_stage_topk, topk_recall, two_stage_topk)
from repro.core.bacam import bacam_scores, pack_bits
from repro.core.binarize import sign_pm1
from repro.core.energy import table2_rows

key = jax.random.PRNGKey(0)

# --- 1. attention in three modes (Eq. 1 of the paper) -------------------
B, H, S, D = 2, 16, 1024, 64
q = jax.random.normal(key, (B, H, S, D))
k = jax.random.normal(jax.random.PRNGKey(1), (B, H, S, D))
v = jax.random.normal(jax.random.PRNGKey(2), (B, H, S, D))

out_dense = dense_reference(q, k, v, causal=True)
out_cam = attention(q, k, v,
                    AttentionSpec(mode="camformer", k_top=32, group_size=16,
                                  stage1_k=2),
                    causal=True)
print("dense vs camformer cosine:",
      float(jnp.sum(out_dense * out_cam)
            / (jnp.linalg.norm(out_dense) * jnp.linalg.norm(out_cam))))

# --- 2. the BA-CAM primitive: packed binary scores ----------------------
qb, kb = sign_pm1(q[0, 0, :4]), sign_pm1(k[0, 0])
scores = bacam_scores(qb, kb)  # XNOR+popcount over packed uint32 words
print("binary scores shape/range:", scores.shape,
      int(scores.min()), int(scores.max()),
      "| packed key bytes:", pack_bits(kb).nbytes, "vs bf16:", kb.size * 2)

# --- 3. hierarchical two-stage top-k (top-2 per 16 -> top-32) ------------
s = jax.random.normal(key, (64, 1024))
tv, ti = two_stage_topk(s, k=32, group_size=16, stage1_k=2)
sv, si = single_stage_topk(s, 32)
print("two-stage recall@32:", float(topk_recall(ti, si).mean()))

# --- 4. the paper's Table II row from the system simulator --------------
row = table2_rows()["CAMformer (ours, simulated)"]
print(f"CAMformer @1GHz: {row['thr_qry_ms']:.0f} qry/ms, "
      f"{row['eff_qry_mj']:.0f} qry/mJ, {row['area_mm2']:.2f} mm^2, "
      f"{row['power_w']:.2f} W")
